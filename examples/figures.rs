//! Regenerate the paper's Fig. 1 and Fig. 2 as PPM images in `out/`.
//!
//! - Fig. 1: 15 points as vectors (scatter) vs. as an image (grid).
//! - Fig. 2: the active search around a '+' query — every radius the
//!   Eq.-1 loop tried, final circle in black.
//!
//! ```sh
//! cargo run --release --example figures && ls out/
//! ```

use std::path::Path;
use std::sync::Arc;

use asnn::data::synthetic::{generate, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::grid::MultiGrid;
use asnn::viz;

fn main() -> asnn::Result<()> {
    let out = Path::new("out");

    // ---- Fig. 1: "15 data points as 2 dimensional vectors … and an
    // image of the points" ----
    let tiny = generate(&SyntheticSpec::blobs(15, 3, 2019));
    viz::render_scatter(&tiny, 600, 5)?.save_ppm(&out.join("fig1_vectors.ppm"))?;
    let grid = MultiGrid::build(&tiny, 600)?;
    viz::render_grid(&grid, 5).save_ppm(&out.join("fig1_image.ppm"))?;
    println!("fig1: out/fig1_vectors.ppm (left) out/fig1_image.ppm (right)");

    // ---- Fig. 2: active search on a 3-class image around '+' ----
    let data = Arc::new(generate(&SyntheticSpec::blobs(400, 3, 2021)));
    let engine = ActiveEngine::new(data, 600, ActiveParams { r0: 60, ..Default::default() })?;
    let query = [0.45, 0.55];
    let circle = engine.search(&query, 11)?;
    let img = viz::render_trace(engine.grid(), (circle.cx, circle.cy), &circle.trace, 2);
    img.save_ppm(&out.join("fig2_trace.ppm"))?;
    println!(
        "fig2: out/fig2_trace.ppm — {} iterations, radii {:?}, final r={} (n={})",
        circle.trace.iterations(),
        circle.trace.steps.iter().map(|s| s.r).collect::<Vec<_>>(),
        circle.r,
        circle.n_inside,
    );
    Ok(())
}
