//! End-to-end driver: the paper's §3 experiment on the full stack.
//!
//! Reproduces the evaluation workload — N uniform 2-D points in 3
//! classes rasterized onto a 3000×3000 image, 100 fresh queries
//! classified with k = 11 nearest neighbors, r₀ = 100 — through every
//! layer: the rust engines, and (when `make artifacts` has run) the
//! PJRT path executing the AOT-compiled Pallas kernels.
//!
//! Prints per-engine elapsed time and agreement with exact kNN (the
//! paper reports "up to 98%"), and records the run for EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example classify_2d
//! ```

use std::path::Path;
use std::sync::Arc;

use asnn::bench::Table;
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::active_pjrt::ActivePjrtEngine;
use asnn::engine::brute::BruteEngine;
use asnn::engine::kdtree::KdTreeEngine;
use asnn::engine::lsh::{LshEngine, LshParams};
use asnn::engine::NnEngine;
use asnn::runtime::RuntimeService;
use asnn::util::timer::Timer;

const N: usize = 50_000;
const QUERIES: usize = 100;
const K: usize = 11;
const RESOLUTION: usize = 3000;

fn main() -> asnn::Result<()> {
    println!("paper §3 experiment: N={N}, {QUERIES} queries, k={K}, {RESOLUTION}² image, r0=100");
    let data = Arc::new(generate(&SyntheticSpec::paper_default(N, 2019)));
    let queries = generate_queries(QUERIES, 2, 42);

    // ground truth: the original kNN
    let brute = BruteEngine::new(data.clone());
    let t = Timer::new();
    let truth: Vec<u16> = queries
        .iter()
        .map(|q| brute.classify(q, K).unwrap())
        .collect();
    let brute_secs = t.elapsed_secs();

    let mut engines: Vec<(Box<dyn NnEngine>, &str)> = vec![
        (Box::new(KdTreeEngine::build(data.clone())), "kdtree"),
        (
            Box::new(LshEngine::build(data.clone(), LshParams::default())),
            "lsh",
        ),
        (
            Box::new(ActiveEngine::new(data.clone(), RESOLUTION, ActiveParams::default())?),
            "active (paper)",
        ),
    ];
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.toml").exists() {
        let service = RuntimeService::spawn(artifacts)?;
        engines.push((
            Box::new(ActivePjrtEngine::new(
                data.clone(),
                RESOLUTION,
                ActiveParams::default(),
                service,
            )?),
            "active-pjrt (AOT/XLA)",
        ));
    } else {
        println!("(artifacts/ missing — run `make artifacts` to exercise the PJRT path)");
    }

    let mut table = Table::new(
        "classification vs exact kNN (paper: up to 98%)",
        &["engine", "agreement_pct", "elapsed_s", "per_query_ms"],
    );
    table.row(&[
        "brute (truth)".into(),
        "100.0".into(),
        format!("{brute_secs:.3}"),
        format!("{:.3}", brute_secs * 1e3 / QUERIES as f64),
    ]);
    for (engine, name) in &engines {
        let t = Timer::new();
        let mut agree = 0usize;
        for (q, want) in queries.iter().zip(&truth) {
            if engine.classify(q, K)? == *want {
                agree += 1;
            }
        }
        let secs = t.elapsed_secs();
        table.row(&[
            name.to_string(),
            format!("{:.1}", 100.0 * agree as f64 / QUERIES as f64),
            format!("{secs:.3}"),
            format!("{:.3}", secs * 1e3 / QUERIES as f64),
        ]);
    }
    table.print();
    println!("(the active rows reproduce the paper's ≈98% agreement claim; see EXPERIMENTS.md TAB-ACC)");
    Ok(())
}
