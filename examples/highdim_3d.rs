//! The paper's §3 higher-dimension sketch, exercised: active search
//! over a 3-D voxel volume, with the memory blow-up the paper warns
//! about measured directly.
//!
//! ```sh
//! cargo run --release --example highdim_3d
//! ```

use std::sync::Arc;

use asnn::bench::Table;
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active3d::{Active3dEngine, Active3dParams};
use asnn::engine::brute::BruteEngine;
use asnn::engine::NnEngine;
use asnn::util::timer::Timer;

const N: usize = 50_000;
const QUERIES: usize = 50;
const K: usize = 11;

fn main() -> asnn::Result<()> {
    println!("3-D active search: N={N}, {QUERIES} queries, k={K}");
    let mut spec = SyntheticSpec::paper_default(N, 99);
    spec.dim = 3;
    let data = Arc::new(generate(&spec));
    let brute = BruteEngine::new(data.clone());
    let queries = generate_queries(QUERIES, 3, 100);
    let truth: Vec<Vec<u32>> = queries
        .iter()
        .map(|q| brute.knn(q, K).unwrap().iter().map(|n| n.id).collect())
        .collect();

    let mut table = Table::new(
        "EXT-3D resolution vs recall/time/memory (the paper's O(R^d) warning)",
        &["resolution", "recall_pct", "mean_query_us", "index_mib"],
    );
    for &res in &[32usize, 64, 128, 256] {
        let engine = Active3dEngine::new(data.clone(), res, Active3dParams::default())?;
        let mem = engine.volume().memory_bytes() as f64 / (1024.0 * 1024.0);
        let t = Timer::new();
        let mut recall = 0.0;
        for (q, ids) in queries.iter().zip(&truth) {
            let hits = engine.knn(q, K)?;
            recall += hits.iter().filter(|h| ids.contains(&h.id)).count() as f64 / K as f64;
        }
        let secs = t.elapsed_secs();
        table.row(&[
            res.to_string(),
            format!("{:.1}", 100.0 * recall / QUERIES as f64),
            format!("{:.1}", secs * 1e6 / QUERIES as f64),
            format!("{mem:.1}"),
        ]);
    }
    table.print();
    println!(
        "note the memory column: R=256 in 3-D already costs what R≈4096 costs in 2-D — \
         the paper's \"much bigger memory\" caveat, quantified."
    );
    Ok(())
}
