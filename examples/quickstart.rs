//! Quickstart: build an index, search, classify — the 60-second tour.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use asnn::data::synthetic::{generate, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;
use asnn::engine::NnEngine;

fn main() -> asnn::Result<()> {
    // 1. a dataset: the paper's workload — uniform 2-D points, 3 classes
    let data = Arc::new(generate(&SyntheticSpec::paper_default(10_000, 42)));
    println!("dataset: {} points, {} classes", data.len(), data.num_classes);

    // 2. the paper's engine: rasterize onto a count image, search by
    //    growing/shrinking a circle (Eq. 1)
    let active = ActiveEngine::new(data.clone(), 1000, ActiveParams::default())?;

    // 3. k nearest neighbors of a fresh point
    let query = [0.5, 0.5];
    let hits = active.knn(&query, 11)?;
    println!("active search found {} neighbors:", hits.len());
    for h in hits.iter().take(5) {
        println!("  id={} dist={:.4} label={}", h.id, h.dist, h.label);
    }

    // 4. compare against the exact ground truth
    let brute = BruteEngine::new(data);
    let truth = brute.knn(&query, 11)?;
    let truth_ids: Vec<u32> = truth.iter().map(|n| n.id).collect();
    let overlap = hits.iter().filter(|h| truth_ids.contains(&h.id)).count();
    println!("overlap with exact kNN: {overlap}/11");

    // 5. classification — the paper's per-class count-image vote
    let label = active.classify(&query, 11)?;
    println!("predicted class at {query:?}: {label}");

    // 6. the search trace (what Fig. 2 visualizes)
    let circle = active.search(&query, 11)?;
    print!("radius trajectory:");
    for s in &circle.trace.steps {
        print!(" r={}→n={}", s.r, s.n);
    }
    println!("  (converged={})", circle.trace.converged);
    Ok(())
}
