//! Serving demo: spin up the coordinator, drive it with concurrent
//! clients over TCP, and report latency/throughput — the paper's
//! algorithm as a deployed service.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use std::sync::Arc;

use asnn::coordinator::server::Client;
use asnn::coordinator::{Metrics, Request, Response, Router, Server};
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;
use asnn::engine::kdtree::KdTreeEngine;
use asnn::util::timer::Timer;

const N: usize = 100_000;
const CLIENTS: usize = 4;
const REQUESTS_PER_CLIENT: usize = 200;

fn main() -> asnn::Result<()> {
    println!("building index over {N} points…");
    let data = Arc::new(generate(&SyntheticSpec::paper_default(N, 7)));
    let metrics = Arc::new(Metrics::new());
    let mut router = Router::new("active", metrics.clone());
    router.register("brute", Arc::new(BruteEngine::new(data.clone())));
    router.register("kdtree", Arc::new(KdTreeEngine::build(data.clone())));
    router.register(
        "active",
        Arc::new(ActiveEngine::new(data, 3000, ActiveParams::default())?),
    );

    let handle = Server::new(Arc::new(router), CLIENTS).spawn("127.0.0.1:0")?;
    println!("serving on {}", handle.addr);

    let addr = handle.addr;
    let t = Timer::new();
    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let queries = generate_queries(REQUESTS_PER_CLIENT, 2, 100 + c as u64);
                let mut client = Client::connect(&addr).expect("connect");
                let mut ok = 0usize;
                for (i, q) in queries.iter().enumerate() {
                    let req = if i % 3 == 0 {
                        Request::Classify { k: 11, x: q[0], y: q[1], engine: None }
                    } else {
                        Request::Knn { k: 11, x: q[0], y: q[1], engine: None }
                    };
                    match client.call(&req).expect("call") {
                        Response::Neighbors(_) | Response::Label(_) => ok += 1,
                        other => panic!("unexpected: {other:?}"),
                    }
                }
                ok
            })
        })
        .collect();
    let total_ok: usize = workers.into_iter().map(|w| w.join().unwrap()).sum();
    let secs = t.elapsed_secs();

    let total = CLIENTS * REQUESTS_PER_CLIENT;
    println!(
        "{total_ok}/{total} requests ok in {secs:.2}s → {:.0} req/s over {CLIENTS} connections",
        total as f64 / secs
    );
    let mut stats_client = Client::connect(&addr)?;
    if let Response::Text(stats) = stats_client.call(&Request::Stats)? {
        println!("server metrics: {stats}");
    }
    handle.shutdown();
    Ok(())
}
