"""AOT pipeline: lower the L2 model to HLO **text** artifacts + manifest.

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts

HLO text is the interchange format — jax ≥ 0.5 serializes
HloModuleProto with 64-bit instruction ids that the rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import K_MAX

# Static shape grid. Window sizes are the coordinator's "zoom levels";
# W = 512 windows are 1 MiB/class (the VMEM budget discussed in
# DESIGN.md). Batch 16 feeds the coordinator's deadline batcher.
WINDOWS = (64, 128, 256, 512)
BATCHES = (1, 16)
KNN_CHUNK = 4096
KNN_BATCHES = (1, 16)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    rust side always un-tuples)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_disk_count(num_classes, window, batch):
    fn = model.make_disk_count(num_classes, window, batch=batch)
    if batch == 1:
        args = (f32(num_classes, window, window), f32(), f32(), f32())
    else:
        args = (f32(batch, num_classes, window, window), f32(batch), f32(), f32())
    return jax.jit(fn).lower(*args)


def lower_neighbor_scan(window):
    fn = model.make_neighbor_scan(window)
    return jax.jit(fn).lower(f32(window, window), f32(), f32())


def lower_knn_chunk(batch):
    fn = model.make_knn_chunk(batch, KNN_CHUNK)
    return jax.jit(fn).lower(f32(batch, 2), f32(KNN_CHUNK, 2), f32())


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--classes", type=int, default=3, help="class channels (paper: 3)")
    ap.add_argument(
        "--windows", type=int, nargs="*", default=list(WINDOWS), help="window sizes"
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = ["version = 1", f"classes = {args.classes}", ""]

    def emit(name, lowered, **meta):
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"[{name}]")
        manifest.append(f'file = "{name}.hlo.txt"')
        for key, val in meta.items():
            if isinstance(val, str):
                manifest.append(f'{key} = "{val}"')
            else:
                manifest.append(f"{key} = {val}")
        manifest.append("")
        print(f"  {name}: {len(text)} chars")

    print(f"lowering artifacts to {args.out} (classes={args.classes})")
    for w in args.windows:
        for b in BATCHES:
            emit(
                f"disk_count_w{w}_b{b}",
                lower_disk_count(args.classes, w, b),
                kind="disk_count",
                window=w,
                batch=b,
                classes=args.classes,
            )
        emit(
            f"neighbor_scan_w{w}",
            lower_neighbor_scan(w),
            kind="neighbor_scan",
            window=w,
            batch=1,
            classes=args.classes,
            k_max=K_MAX,
        )
    for b in KNN_BATCHES:
        emit(
            f"knn_chunk_b{b}",
            lower_knn_chunk(b),
            kind="knn_chunk",
            batch=b,
            chunk=KNN_CHUNK,
            k_max=K_MAX,
        )

    with open(os.path.join(args.out, "manifest.toml"), "w") as f:
        f.write("\n".join(manifest))
    print(f"wrote manifest with {len([l for l in manifest if l.startswith('[')])} artifacts")


if __name__ == "__main__":
    main()
