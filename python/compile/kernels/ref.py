"""Pure-jnp oracles for every Pallas kernel — the correctness signal.

Each ``*_ref`` mirrors its kernel's semantics with straightforward
jax.numpy so pytest can ``assert_allclose`` kernel vs. oracle across
shape/dtype sweeps (hypothesis drives the sweeps in
``python/tests/``).
"""

import jax
import jax.numpy as jnp

K_MAX = 32


def _pixel_offsets(w):
    """dy/dx offsets of every pixel in a w-by-w window from its center."""
    c = w // 2
    ys = jnp.arange(w, dtype=jnp.float32) - c
    xs = jnp.arange(w, dtype=jnp.float32) - c
    dy = ys[:, None] * jnp.ones((1, w), jnp.float32)
    dx = xs[None, :] * jnp.ones((w, 1), jnp.float32)
    return dy, dx


def disk_mask(w, r, metric_l1):
    """Boolean in-circle mask for a w-window; r scalar; metric flag scalar."""
    dy, dx = _pixel_offsets(w)
    l2 = dx * dx + dy * dy <= r * r
    l1 = jnp.abs(dx) + jnp.abs(dy) <= r
    return jnp.where(metric_l1 > 0.5, l1, l2)


def disk_count_ref(window, r, k, metric_l1):
    """Oracle for the disk_count kernel + Eq. 1 epilogue.

    window: [C, W, W] per-class counts; returns (per-class counts [C],
    total scalar, Eq.-1 next radius scalar).
    """
    w = window.shape[-1]
    mask = disk_mask(w, r, metric_l1).astype(jnp.float32)
    counts = jnp.sum(window * mask[None, :, :], axis=(1, 2))
    total = jnp.sum(counts)
    # Eq. 1 with the n = 0 doubling guard (matches rust RadiusPolicy)
    next_r = jnp.where(
        total > 0.0,
        jnp.round(r * jnp.sqrt(k / jnp.maximum(total, 1.0))),
        jnp.round(r * 2.0),
    )
    next_r = jnp.maximum(next_r, 1.0)
    return counts, total, next_r


def neighbor_scan_ref(window_total, r, metric_l1, k_max=K_MAX):
    """Oracle for the neighbor_scan kernel: masked distance map + top-k.

    window_total: [W, W] total counts. Returns (dists [k_max],
    flat pixel indices [k_max] i32); +inf / -1 padding.
    """
    w = window_total.shape[-1]
    dy, dx = _pixel_offsets(w)
    d_l2 = dx * dx + dy * dy  # squared
    d_l1 = jnp.abs(dx) + jnp.abs(dy)
    dist = jnp.where(metric_l1 > 0.5, d_l1, d_l2)
    limit = jnp.where(metric_l1 > 0.5, r, r * r)
    valid = (window_total > 0.0) & (dist <= limit)
    scored = jnp.where(valid, dist, jnp.inf).reshape(-1)
    neg_top, idx = jax.lax.top_k(-scored, k_max)
    dists = -neg_top
    idx = jnp.where(jnp.isfinite(dists), idx, -1).astype(jnp.int32)
    return dists, idx


def knn_chunk_ref(queries, chunk, valid, k_max=K_MAX):
    """Oracle for the knn_chunk kernel: exact top-k over one chunk.

    queries: [B, 2], chunk: [N, 2], valid: live prefix length.
    Returns (d2 [B, k_max], indices [B, k_max] i32), +inf/-1 padded.
    """
    d2 = (
        jnp.sum(queries**2, axis=1)[:, None]
        + jnp.sum(chunk**2, axis=1)[None, :]
        - 2.0 * queries @ chunk.T
    )
    n = chunk.shape[0]
    col = jnp.arange(n, dtype=jnp.float32)[None, :]
    d2 = jnp.where(col < valid, d2, jnp.inf)
    neg_top, idx = jax.lax.top_k(-d2, k_max)
    dists = -neg_top
    idx = jnp.where(jnp.isfinite(dists), idx, -1).astype(jnp.int32)
    return dists, idx


