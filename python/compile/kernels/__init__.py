"""L1 Pallas kernels for the active-search hot spots.

All kernels are lowered with ``interpret=True``: the CPU PJRT client
cannot execute Mosaic custom-calls, and interpret mode lowers to plain
HLO that any backend runs. On a real TPU the same kernels compile with
``interpret=False`` — the BlockSpecs below are written for VMEM tiling
(see DESIGN.md §Hardware-Adaptation).
"""

from . import disk_count, knn_chunk, neighbor_scan, ref  # noqa: F401
