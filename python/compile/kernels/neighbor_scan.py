"""Pallas kernel: masked distance map for in-circle neighbor extraction.

Produces, for a window of the total-count image, the pixel-space
distance of every *occupied, in-circle* pixel from the window center
(+inf elsewhere). The L2 model composes this with ``lax.top_k`` to rank
the K nearest occupied pixels; rust expands pixels back to point ids
through the grid's bucket index.

TPU mapping: the W×W window is one VMEM block (≤ 1 MiB at W = 512);
distance and masks come from iota, so the kernel streams the window
once and writes the same-shape map — pure bandwidth, no MXU needed.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(win_ref, r_ref, m_ref, out_ref):
    """win_ref: [W, W] totals; out_ref: [W, W] masked distances."""
    w = win_ref.shape[-1]
    c = w // 2
    dy = jax.lax.broadcasted_iota(jnp.float32, (w, w), 0) - c
    dx = jax.lax.broadcasted_iota(jnp.float32, (w, w), 1) - c
    r = r_ref[0, 0]
    l1 = m_ref[0, 0] > 0.5
    dist = jnp.where(l1, jnp.abs(dx) + jnp.abs(dy), dx * dx + dy * dy)
    limit = jnp.where(l1, r, r * r)
    valid = (win_ref[...] > 0.0) & (dist <= limit)
    out_ref[...] = jnp.where(valid, dist, jnp.inf)


def masked_distance_map(window_total, r, metric_l1, interpret=True):
    """[W, W] totals → [W, W] masked distance map (+inf = not a hit)."""
    w = window_total.shape[-1]
    r2d = jnp.reshape(r, (1, 1)).astype(jnp.float32)
    m2d = jnp.reshape(metric_l1, (1, 1)).astype(jnp.float32)
    return pl.pallas_call(
        _kernel,
        in_specs=[
            pl.BlockSpec((w, w), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((w, w), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((w, w), jnp.float32),
        interpret=interpret,
    )(window_total, r2d, m2d)
