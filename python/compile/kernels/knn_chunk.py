"""Pallas kernel: brute-force distance tile for the baseline kNN.

The paper's "original kNN" comparator, phrased for the MXU: the B×N
squared-distance matrix is computed via the ‖q‖² + ‖p‖² − 2·q·pᵀ
expansion, whose dominant term is a matmul — exactly what the systolic
array wants (the CUDA equivalent would be a WMMA tile; see DESIGN.md
§Hardware-Adaptation). Columns past ``valid`` (chunk padding) are set
to +inf.

TPU mapping: B ≤ 16 queries × N = 4096 chunk points = 256 KiB output
tile in VMEM; inputs are tiny. One block, one pass.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, p_ref, v_ref, out_ref):
    """q_ref: [B, 2]; p_ref: [N, 2]; v_ref: [1, 1]; out_ref: [B, N]."""
    q = q_ref[...]
    p = p_ref[...]
    qn = jnp.sum(q * q, axis=1, keepdims=True)          # [B, 1]
    pn = jnp.sum(p * p, axis=1, keepdims=True).T        # [1, N]
    cross = jnp.dot(q, p.T)                             # MXU matmul [B, N]
    d2 = qn + pn - 2.0 * cross
    d2 = jnp.maximum(d2, 0.0)                           # numeric floor
    n = p_ref.shape[0]
    col = jax.lax.broadcasted_iota(jnp.float32, (q.shape[0], n), 1)
    out_ref[...] = jnp.where(col < v_ref[0, 0], d2, jnp.inf)


def distance_tile(queries, chunk, valid, interpret=True):
    """[B,2] × [N,2] → [B,N] masked squared distances."""
    b = queries.shape[0]
    n = chunk.shape[0]
    v2d = jnp.reshape(valid, (1, 1)).astype(jnp.float32)
    return pl.pallas_call(
        _kernel,
        in_specs=[
            pl.BlockSpec((b, 2), lambda: (0, 0)),
            pl.BlockSpec((n, 2), lambda: (0, 0)),
            pl.BlockSpec((1, 1), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, n), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(queries, chunk, v2d)
