"""Pallas kernel: per-class masked count of points inside a scan circle.

The paper's hot spot — "checking all the inner pixels of the current
circle" — phrased as a data-parallel masked reduction over a window of
the per-class count image.

TPU mapping (DESIGN.md §Hardware-Adaptation): one class plane of a
W ≤ 512 window is ≤ 1 MiB f32 — a single VMEM block. The grid iterates
classes, so HBM→VMEM streams each plane exactly once per call; the mask
is computed from iota (no memory traffic) and fused into the reduction.
Arithmetic intensity ≈ 3 flops/byte — the kernel is bandwidth-bound and
the BlockSpec keeps it at one pass.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(win_ref, r_ref, m_ref, out_ref):
    """One class plane: win_ref [1, W, W]; r/m [1, 1]; out [1]."""
    w = win_ref.shape[-1]
    c = w // 2
    dy = jax.lax.broadcasted_iota(jnp.float32, (w, w), 0) - c
    dx = jax.lax.broadcasted_iota(jnp.float32, (w, w), 1) - c
    r = r_ref[0, 0]
    inside_l2 = dx * dx + dy * dy <= r * r
    inside_l1 = jnp.abs(dx) + jnp.abs(dy) <= r
    mask = jnp.where(m_ref[0, 0] > 0.5, inside_l1, inside_l2)
    out_ref[0] = jnp.sum(win_ref[0] * mask.astype(jnp.float32))


def disk_count_classes(window, r, metric_l1, interpret=True):
    """Per-class in-circle counts.

    window: [C, W, W] f32; r, metric_l1: scalars. Returns counts [C].
    """
    c, w, _ = window.shape
    r2d = jnp.reshape(r, (1, 1)).astype(jnp.float32)
    m2d = jnp.reshape(metric_l1, (1, 1)).astype(jnp.float32)
    return pl.pallas_call(
        _kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, w, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        interpret=interpret,
    )(window, r2d, m2d)
