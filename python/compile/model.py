"""L2 jax model: the computations the rust coordinator executes via
PJRT, composed from the L1 Pallas kernels.

Each ``make_*`` returns a pure jax function with **static shapes**
(PJRT executables are shape-specialized); ``aot.py`` lowers one
executable per (window, batch) combination and records them in the
artifact manifest.

Output conventions (mirrored by ``rust/src/runtime/artifacts.rs``):

- ``disk_count``:    (class_counts, total, next_r)
- ``neighbor_scan``: (dists [K_MAX], flat indices [K_MAX] i32)
- ``knn_chunk``:     (d2 [B, K_MAX], indices [B, K_MAX] i32)
"""

import jax
import jax.numpy as jnp

from .kernels import disk_count as dc
from .kernels import knn_chunk as kc
from .kernels import neighbor_scan as ns
from .kernels.ref import K_MAX


def bottom_k(x, k):
    """Smallest-k of a 1-D array as (values, indices i32), ascending;
    +inf/-1 padding for absent entries.

    Implemented as k iterations of masked argmin (scan) instead of
    ``lax.top_k``: jax lowers top_k to a `topk(..., largest=true)` HLO
    attribute that the xla_extension 0.5.1 text parser rejects, while
    argmin/scatter/while round-trip cleanly.
    """

    def body(cur, _):
        i = jnp.argmin(cur)
        v = cur[i]
        return cur.at[i].set(jnp.inf), (v, i.astype(jnp.int32))

    _, (vals, idxs) = jax.lax.scan(body, x, None, length=k)
    idxs = jnp.where(jnp.isfinite(vals), idxs, -1)
    return vals, idxs


def eq1_next_radius(r, k, total):
    """Paper Eq. 1 with the n = 0 doubling guard (matches the rust
    ``RadiusPolicy``): r ← round(r·√(k/n)), or 2r when the circle is
    empty; never below 1."""
    grown = jnp.round(r * 2.0)
    adapted = jnp.round(r * jnp.sqrt(k / jnp.maximum(total, 1.0)))
    return jnp.maximum(jnp.where(total > 0.0, adapted, grown), 1.0)


def make_disk_count(num_classes, window, batch=1, interpret=True):
    """Active-search step: count per class inside the circle, emit the
    Eq.-1 next radius.

    batch = 1 signature: (window [C,W,W], r, k, metric) →
        (counts [C], total [], next_r [])
    batch > 1 signature: (windows [B,C,W,W], rs [B], k, metric) →
        (counts [B,C], totals [B], next_rs [B])
    """

    def single(win, r, k, metric_l1):
        counts = dc.disk_count_classes(win, r, metric_l1, interpret=interpret)
        total = jnp.sum(counts)
        return counts, total, eq1_next_radius(r, k, total)

    if batch == 1:
        def fn(win, r, k, metric_l1):
            return single(win, r, k, metric_l1)
        return fn

    def fn_batch(wins, rs, k, metric_l1):
        counts, totals, next_rs = jax.vmap(
            lambda w, r: single(w, r, k, metric_l1)
        )(wins, rs)
        return counts, totals, next_rs

    return fn_batch


def make_neighbor_scan(window, k_max=K_MAX, interpret=True):
    """Final-circle extraction: (window_total [W,W], r, metric) →
    top-k_max occupied pixels as (dists, flat indices)."""

    def fn(win_total, r, metric_l1):
        dist_map = ns.masked_distance_map(win_total, r, metric_l1, interpret=interpret)
        return bottom_k(dist_map.reshape(-1), k_max)

    return fn


def make_knn_chunk(batch, chunk, k_max=K_MAX, interpret=True):
    """Brute-force baseline over one point chunk: (queries [B,2],
    points [N,2], valid) → per-query (d2 [B,K], idx [B,K])."""

    def fn(queries, points, valid):
        d2 = kc.distance_tile(queries, points, valid, interpret=interpret)
        dists, idx = jax.vmap(lambda row: bottom_k(row, k_max))(d2)
        return dists, idx

    return fn
