"""L2 model composition: disk_count step + Eq. 1, batching, and AOT
lowering shape checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref
from tests.conftest import random_window


def test_disk_count_step_outputs(rng):
    fn = model.make_disk_count(3, 32)
    win = random_window(rng, 3, 32, density=0.2)
    counts, total, next_r = fn(jnp.array(win), jnp.float32(8), jnp.float32(11), jnp.float32(0))
    assert counts.shape == (3,)
    assert float(total) == float(np.asarray(counts).sum())
    want_c, want_t, want_r = ref.disk_count_ref(
        jnp.array(win), jnp.float32(8), jnp.float32(11), jnp.float32(0)
    )
    assert_allclose(np.asarray(counts), np.asarray(want_c))
    assert float(next_r) == float(want_r)


def test_eq1_guards():
    # n = 0 doubles; result never below 1
    assert float(model.eq1_next_radius(jnp.float32(50), jnp.float32(11), jnp.float32(0))) == 100.0
    assert float(model.eq1_next_radius(jnp.float32(1), jnp.float32(1), jnp.float32(10_000))) == 1.0
    # n == k keeps radius
    assert float(model.eq1_next_radius(jnp.float32(100), jnp.float32(11), jnp.float32(11))) == 100.0


def test_batched_disk_count_matches_loop(rng):
    b, c, w = 4, 3, 16
    fn_b = model.make_disk_count(c, w, batch=b)
    fn_1 = model.make_disk_count(c, w, batch=1)
    wins = np.stack([random_window(rng, c, w, density=0.2) for _ in range(b)])
    rs = np.array([3.0, 5.0, 7.0, 2.0], np.float32)
    counts, totals, next_rs = fn_b(jnp.array(wins), jnp.array(rs), jnp.float32(11), jnp.float32(0))
    assert counts.shape == (b, c)
    for i in range(b):
        c1, t1, r1 = fn_1(jnp.array(wins[i]), jnp.float32(rs[i]), jnp.float32(11), jnp.float32(0))
        assert_allclose(np.asarray(counts)[i], np.asarray(c1))
        assert float(totals[i]) == float(t1)
        assert float(next_rs[i]) == float(r1)


def test_jit_lowering_all_kinds():
    # every artifact family lowers to HLO text without error
    for lowered in [
        aot.lower_disk_count(3, 16, 1),
        aot.lower_disk_count(3, 16, 4),
        aot.lower_neighbor_scan(16),
        aot.lower_knn_chunk(2),
    ]:
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert len(text) > 500


def test_lowered_disk_count_executes_consistently(rng):
    # the lowered computation (what rust runs) == the eager one
    fn = model.make_disk_count(3, 16)
    win = random_window(rng, 3, 16, density=0.3)
    args = (jnp.array(win), jnp.float32(4), jnp.float32(11), jnp.float32(0))
    eager = fn(*args)
    compiled = jax.jit(fn).lower(*args).compile()(*args)
    for e, c in zip(eager, compiled):
        assert_allclose(np.asarray(e), np.asarray(c))


@pytest.mark.parametrize("w", [8, 64])
def test_window_size_parametrization(w):
    fn = model.make_disk_count(2, w)
    win = jnp.zeros((2, w, w), jnp.float32).at[0, w // 2, w // 2].set(3.0)
    counts, total, _ = fn(win, jnp.float32(1), jnp.float32(3), jnp.float32(0))
    assert float(total) == 3.0
    assert float(counts[0]) == 3.0
