"""Shared fixtures for the kernel/model test suite."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def random_window(rng, c, w, density=0.05, max_count=4):
    """Sparse random per-class count window [C, W, W] f32."""
    win = np.zeros((c, w, w), np.float32)
    n = max(1, int(density * c * w * w))
    cs = rng.integers(0, c, n)
    ys = rng.integers(0, w, n)
    xs = rng.integers(0, w, n)
    for ci, yi, xi in zip(cs, ys, xs):
        win[ci, yi, xi] += float(rng.integers(1, max_count + 1))
    return win
