"""neighbor_scan kernel + top-k composition vs. oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import neighbor_scan as ns
from compile.kernels import ref
from tests.conftest import random_window


def totals(rng, w, density=0.05):
    return random_window(rng, 1, w, density=density)[0]


@pytest.mark.parametrize("w", [8, 16, 32])
@pytest.mark.parametrize("metric", [0.0, 1.0])
def test_distance_map_matches_ref(rng, w, metric):
    win = totals(rng, w, density=0.2)
    r = jnp.float32(w / 2.5)
    got = ns.masked_distance_map(jnp.array(win), r, jnp.float32(metric))
    dy, dx = ref._pixel_offsets(w)
    dist = jnp.where(metric > 0.5, jnp.abs(dx) + jnp.abs(dy), dx * dx + dy * dy)
    limit = jnp.where(metric > 0.5, r, r * r)
    want = jnp.where((jnp.array(win) > 0) & (dist <= limit), dist, jnp.inf)
    assert_allclose(np.asarray(got), np.asarray(want))


def test_empty_window_all_inf(rng):
    win = np.zeros((16, 16), np.float32)
    got = ns.masked_distance_map(jnp.array(win), jnp.float32(8), jnp.float32(0))
    assert np.all(np.isinf(np.asarray(got)))


def test_model_topk_returns_sorted_hits(rng):
    win = totals(rng, 32, density=0.1)
    fn = model.make_neighbor_scan(32)
    dists, idx = fn(jnp.array(win), jnp.float32(12), jnp.float32(0))
    d = np.asarray(dists)
    i = np.asarray(idx)
    live = np.isfinite(d)
    # ascending among live entries, -1 padding elsewhere
    assert np.all(np.diff(d[live]) >= 0)
    assert np.all(i[~live] == -1)
    # every live index points at an occupied in-circle pixel
    for dist_val, flat in zip(d[live], i[live]):
        y, x = divmod(int(flat), 32)
        assert win[y, x] > 0
        dd = (y - 16) ** 2 + (x - 16) ** 2
        assert dd <= 12 * 12
        assert abs(dd - dist_val) < 1e-5


def test_model_matches_oracle(rng):
    win = totals(rng, 24, density=0.15)
    fn = model.make_neighbor_scan(24)
    got_d, got_i = fn(jnp.array(win), jnp.float32(9), jnp.float32(0))
    want_d, want_i = ref.neighbor_scan_ref(jnp.array(win), jnp.float32(9), jnp.float32(0))
    assert_allclose(np.asarray(got_d), np.asarray(want_d))
    # indices may tie-permute within equal distances; compare sets of
    # (dist, occupied) pairs instead of raw index order
    live = np.isfinite(np.asarray(got_d))
    assert set(np.asarray(got_i)[live].tolist()) == set(np.asarray(want_i)[live].tolist())


@settings(max_examples=20, deadline=None)
@given(
    w=st.sampled_from([8, 16, 32]),
    r=st.floats(min_value=0.5, max_value=20.0),
    metric=st.sampled_from([0.0, 1.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_hit_counts(w, r, metric, seed):
    rng = np.random.default_rng(seed)
    win = totals(rng, w, density=0.08)
    fn = model.make_neighbor_scan(w)
    dists, idx = fn(jnp.array(win), jnp.float32(r), jnp.float32(metric))
    live = int(np.isfinite(np.asarray(dists)).sum())
    # oracle count of occupied in-circle pixels, capped at K_MAX
    dy, dx = np.mgrid[0:w, 0:w]
    dy = dy - w // 2
    dx = dx - w // 2
    if metric > 0.5:
        inside = (np.abs(dx) + np.abs(dy)) <= r
    else:
        inside = (dx * dx + dy * dy) <= r * r
    want = int(((win > 0) & inside).sum())
    assert live == min(want, ref.K_MAX)
