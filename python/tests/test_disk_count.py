"""disk_count kernel vs. pure-jnp oracle — the core L1 correctness
signal, swept over shapes, radii, and metrics (hypothesis drives the
randomized sweep)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import disk_count as dc
from compile.kernels import ref
from tests.conftest import random_window


@pytest.mark.parametrize("w", [8, 16, 32, 64])
@pytest.mark.parametrize("metric", [0.0, 1.0])
def test_kernel_matches_ref(rng, w, metric):
    win = random_window(rng, 3, w)
    r = jnp.float32(w / 3)
    counts = dc.disk_count_classes(jnp.array(win), r, jnp.float32(metric))
    want, _, _ = ref.disk_count_ref(jnp.array(win), r, jnp.float32(11), jnp.float32(metric))
    assert_allclose(np.asarray(counts), np.asarray(want), rtol=0, atol=0)


def test_zero_radius_counts_center_only(rng):
    win = random_window(rng, 3, 16, density=0.5)
    counts = dc.disk_count_classes(jnp.array(win), jnp.float32(0), jnp.float32(0))
    assert_allclose(np.asarray(counts), win[:, 8, 8])


def test_huge_radius_counts_all(rng):
    win = random_window(rng, 3, 32)
    counts = dc.disk_count_classes(jnp.array(win), jnp.float32(1000), jnp.float32(0))
    assert_allclose(np.asarray(counts), win.sum(axis=(1, 2)))


def test_l1_subset_of_l2(rng):
    win = random_window(rng, 3, 32, density=0.3)
    r = jnp.float32(9)
    l2 = dc.disk_count_classes(jnp.array(win), r, jnp.float32(0)).sum()
    l1 = dc.disk_count_classes(jnp.array(win), r, jnp.float32(1)).sum()
    assert float(l1) <= float(l2)


def test_single_class_window(rng):
    win = random_window(rng, 1, 16)
    counts = dc.disk_count_classes(jnp.array(win), jnp.float32(5), jnp.float32(0))
    assert counts.shape == (1,)
    want, _, _ = ref.disk_count_ref(jnp.array(win), jnp.float32(5), jnp.float32(3), jnp.float32(0))
    assert_allclose(np.asarray(counts), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    w=st.sampled_from([8, 16, 24, 32]),
    c=st.integers(min_value=1, max_value=4),
    r=st.floats(min_value=0.0, max_value=40.0),
    metric=st.sampled_from([0.0, 1.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_sweep(w, c, r, metric, seed):
    rng = np.random.default_rng(seed)
    win = random_window(rng, c, w, density=0.1)
    counts = dc.disk_count_classes(jnp.array(win), jnp.float32(r), jnp.float32(metric))
    want, total, next_r = ref.disk_count_ref(
        jnp.array(win), jnp.float32(r), jnp.float32(11), jnp.float32(metric)
    )
    assert_allclose(np.asarray(counts), np.asarray(want), rtol=0, atol=0)
    # counts are conservative: never exceed the full window sum
    assert float(total) <= float(win.sum()) + 1e-6
    assert float(next_r) >= 1.0
