"""knn_chunk kernel (brute baseline tile) vs. oracle and vs. numpy."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import knn_chunk as kc
from compile.kernels import ref


def test_distance_tile_exact(rng):
    q = rng.random((4, 2)).astype(np.float32)
    p = rng.random((64, 2)).astype(np.float32)
    got = kc.distance_tile(jnp.array(q), jnp.array(p), jnp.float32(64))
    want = ((q[:, None, :] - p[None, :, :]) ** 2).sum(-1)
    assert_allclose(np.asarray(got), want, atol=1e-5)


def test_padding_masked(rng):
    q = rng.random((2, 2)).astype(np.float32)
    p = rng.random((32, 2)).astype(np.float32)
    got = np.asarray(kc.distance_tile(jnp.array(q), jnp.array(p), jnp.float32(10)))
    assert np.all(np.isinf(got[:, 10:]))
    assert np.all(np.isfinite(got[:, :10]))


def test_model_topk_matches_numpy_sort(rng):
    b, n, valid = 3, 128, 100
    q = rng.random((b, 2)).astype(np.float32)
    p = rng.random((n, 2)).astype(np.float32)
    fn = model.make_knn_chunk(b, n)
    dists, idx = fn(jnp.array(q), jnp.array(p), jnp.float32(valid))
    d2 = ((q[:, None, :] - p[None, :valid, :]) ** 2).sum(-1)
    for bi in range(b):
        order = np.argsort(d2[bi])[: ref.K_MAX]
        assert_allclose(np.asarray(dists)[bi], d2[bi][order], atol=1e-5)
        # index sets agree modulo distance ties
        assert set(np.asarray(idx)[bi].tolist()) == set(order.tolist())


def test_model_matches_oracle(rng):
    q = rng.random((2, 2)).astype(np.float32)
    p = rng.random((64, 2)).astype(np.float32)
    fn = model.make_knn_chunk(2, 64)
    got_d, _ = fn(jnp.array(q), jnp.array(p), jnp.float32(50))
    want_d, _ = ref.knn_chunk_ref(jnp.array(q), jnp.array(p), jnp.float32(50))
    assert_allclose(np.asarray(got_d), np.asarray(want_d), atol=1e-5)


def test_query_on_dataset_point_is_rank_zero(rng):
    p = rng.random((64, 2)).astype(np.float32)
    q = p[7:8]
    fn = model.make_knn_chunk(1, 64)
    dists, idx = fn(jnp.array(q), jnp.array(p), jnp.float32(64))
    assert int(np.asarray(idx)[0, 0]) == 7
    assert float(np.asarray(dists)[0, 0]) < 1e-9


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=8),
    n=st.sampled_from([33, 64, 128]),
    valid_frac=st.floats(min_value=0.1, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_tile(b, n, valid_frac, seed):
    rng = np.random.default_rng(seed)
    valid = max(1, int(n * valid_frac))
    q = rng.random((b, 2)).astype(np.float32)
    p = rng.random((n, 2)).astype(np.float32)
    got = np.asarray(kc.distance_tile(jnp.array(q), jnp.array(p), jnp.float32(valid)))
    want = ((q[:, None, :] - p[None, :, :]) ** 2).sum(-1)
    assert_allclose(got[:, :valid], want[:, :valid], atol=1e-4)
    assert np.all(np.isinf(got[:, valid:]))
