//! Runtime service thread: the PJRT client and compiled executables are
//! not `Send` (the `xla` crate wraps raw PJRT pointers in `Rc`), so one
//! dedicated thread owns them and serves execution requests over a
//! channel — the same single-runtime-thread-per-device shape real
//! serving systems use. [`RuntimeService`] handles are `Clone + Send +
//! Sync` and can sit inside any engine.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};

use super::artifacts::{DiskCountOut, KnnChunkOut, NeighborScanOut};
use super::manifest::ArtifactMeta;
use super::PjrtRuntime;
use crate::error::{AsnnError, Result};

enum Job {
    DiskCount {
        artifact: String,
        window: Vec<f32>,
        r: f32,
        k: f32,
        metric_l1: bool,
        reply: Sender<Result<DiskCountOut>>,
    },
    DiskCountBatch {
        artifact: String,
        windows: Vec<f32>,
        rs: Vec<f32>,
        k: f32,
        metric_l1: bool,
        reply: Sender<Result<Vec<DiskCountOut>>>,
    },
    NeighborScan {
        artifact: String,
        window: Vec<f32>,
        r: f32,
        metric_l1: bool,
        reply: Sender<Result<NeighborScanOut>>,
    },
    KnnChunk {
        artifact: String,
        queries: Vec<f32>,
        chunk: Vec<f32>,
        valid: usize,
        reply: Sender<Result<KnnChunkOut>>,
    },
}

/// Cloneable, thread-safe handle to the runtime thread.
#[derive(Clone)]
pub struct RuntimeService {
    tx: Sender<Job>,
    metas: Vec<ArtifactMeta>,
    platform: String,
}

// Sender<T> is Send+!Sync in std; wrap sends behind a clone per call.
// RuntimeService is used via &self from many threads, so guard the
// sender with a mutex-free clone-on-call pattern: Sender is actually
// Sync in Rust >= 1.72 (documented Send+Sync). Nothing more needed.

impl RuntimeService {
    /// Spawn the runtime thread: create the CPU client, compile every
    /// artifact in `dir`, and start serving.
    pub fn spawn(dir: PathBuf) -> Result<Self> {
        let (tx, rx) = channel::<Job>();
        let (boot_tx, boot_rx) = channel::<Result<(Vec<ArtifactMeta>, String)>>();
        std::thread::Builder::new()
            .name("asnn-pjrt".into())
            .spawn(move || {
                let (registry, platform) = match PjrtRuntime::cpu()
                    .and_then(|rt| Ok((rt.load_registry(&dir)?, rt.platform())))
                {
                    Ok(ok) => ok,
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                let metas: Vec<ArtifactMeta> =
                    registry.manifest.iter().cloned().collect();
                let _ = boot_tx.send(Ok((metas, platform)));
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::DiskCount { artifact, window, r, k, metric_l1, reply } => {
                            let out = registry
                                .get(&artifact)
                                .ok_or_else(|| missing(&artifact))
                                .and_then(|a| a.disk_count(&window, r, k, metric_l1));
                            let _ = reply.send(out);
                        }
                        Job::DiskCountBatch { artifact, windows, rs, k, metric_l1, reply } => {
                            let out = registry
                                .get(&artifact)
                                .ok_or_else(|| missing(&artifact))
                                .and_then(|a| a.disk_count_batch(&windows, &rs, k, metric_l1));
                            let _ = reply.send(out);
                        }
                        Job::NeighborScan { artifact, window, r, metric_l1, reply } => {
                            let out = registry
                                .get(&artifact)
                                .ok_or_else(|| missing(&artifact))
                                .and_then(|a| a.neighbor_scan(&window, r, metric_l1));
                            let _ = reply.send(out);
                        }
                        Job::KnnChunk { artifact, queries, chunk, valid, reply } => {
                            let out = registry
                                .get(&artifact)
                                .ok_or_else(|| missing(&artifact))
                                .and_then(|a| a.knn_chunk(&queries, &chunk, valid));
                            let _ = reply.send(out);
                        }
                    }
                }
            })
            .map_err(|e| AsnnError::Runtime(format!("spawn runtime thread: {e}")))?;
        let (metas, platform) = boot_rx
            .recv()
            .map_err(|_| AsnnError::Runtime("runtime thread died during boot".into()))??;
        Ok(Self { tx, metas, platform })
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Manifest metadata (captured at boot).
    pub fn metas(&self) -> &[ArtifactMeta] {
        &self.metas
    }

    /// Window sizes with batch-1 disk_count artifacts, ascending.
    pub fn disk_count_windows(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .metas
            .iter()
            .filter(|m| m.kind == "disk_count" && m.batch == 1)
            .map(|m| m.window)
            .collect();
        v.sort_unstable();
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.iter().find(|m| m.name == name)
    }

    fn call<T>(&self, build: impl FnOnce(Sender<Result<T>>) -> Job) -> Result<T> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(build(reply_tx))
            .map_err(|_| AsnnError::Runtime("runtime thread has exited".into()))?;
        reply_rx
            .recv()
            .map_err(|_| AsnnError::Runtime("runtime thread dropped the reply".into()))?
    }

    pub fn disk_count(
        &self,
        artifact: &str,
        window: Vec<f32>,
        r: f32,
        k: f32,
        metric_l1: bool,
    ) -> Result<DiskCountOut> {
        self.call(|reply| Job::DiskCount {
            artifact: artifact.to_string(),
            window,
            r,
            k,
            metric_l1,
            reply,
        })
    }

    pub fn disk_count_batch(
        &self,
        artifact: &str,
        windows: Vec<f32>,
        rs: Vec<f32>,
        k: f32,
        metric_l1: bool,
    ) -> Result<Vec<DiskCountOut>> {
        self.call(|reply| Job::DiskCountBatch {
            artifact: artifact.to_string(),
            windows,
            rs,
            k,
            metric_l1,
            reply,
        })
    }

    pub fn neighbor_scan(
        &self,
        artifact: &str,
        window: Vec<f32>,
        r: f32,
        metric_l1: bool,
    ) -> Result<NeighborScanOut> {
        self.call(|reply| Job::NeighborScan {
            artifact: artifact.to_string(),
            window,
            r,
            metric_l1,
            reply,
        })
    }

    pub fn knn_chunk(
        &self,
        artifact: &str,
        queries: Vec<f32>,
        chunk: Vec<f32>,
        valid: usize,
    ) -> Result<KnnChunkOut> {
        self.call(|reply| Job::KnnChunk {
            artifact: artifact.to_string(),
            queries,
            chunk,
            valid,
            reply,
        })
    }
}

fn missing(name: &str) -> AsnnError {
    AsnnError::Runtime(format!("artifact {name:?} not in registry"))
}
