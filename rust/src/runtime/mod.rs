//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the rust hot path.
//!
//! `python/compile/aot.py` lowers the L2 jax model (which calls the L1
//! Pallas kernels) to **HLO text** — the interchange format that
//! round-trips through xla_extension 0.5.1 (serialized jax ≥ 0.5
//! protos carry 64-bit instruction ids it rejects). This module wraps
//! the `xla` crate: CPU PJRT client → `HloModuleProto::from_text_file`
//! → compile once → typed execute helpers.

pub mod artifacts;
pub mod manifest;
pub mod service;

pub use artifacts::{ArtifactRegistry, CompiledArtifact};
pub use manifest::{ArtifactMeta, Manifest};
pub use service::RuntimeService;

use std::path::Path;

use crate::error::{AsnnError, Result};

/// Convert an `xla` crate error into our runtime error domain.
pub(crate) fn xla_err(e: xla::Error) -> AsnnError {
    AsnnError::Runtime(format!("{e:?}"))
}

/// Owning wrapper around the PJRT CPU client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client (the only backend in this testbed; the
    /// same artifacts compile on TPU PJRT plugins when the kernels are
    /// lowered without `interpret=True` — see DESIGN.md).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(xla_err)?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text file.
    pub fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| AsnnError::Runtime(format!("non-utf8 path {path:?}")))?,
        )
        .map_err(xla_err)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(xla_err)
    }

    /// Load every artifact listed in `<dir>/manifest.toml`.
    pub fn load_registry(&self, dir: &Path) -> Result<ArtifactRegistry> {
        ArtifactRegistry::load(self, dir)
    }
}

/// Execute a compiled module lowered with `return_tuple=True` and
/// return the un-tupled output literals.
pub fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(inputs).map_err(xla_err)?;
    let buf = result
        .first()
        .and_then(|d| d.first())
        .ok_or_else(|| AsnnError::Runtime("executable returned no buffers".into()))?;
    let lit = buf.to_literal_sync().map_err(xla_err)?;
    lit.to_tuple().map_err(xla_err)
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    if expect as usize != data.len() {
        return Err(AsnnError::Runtime(format!(
            "literal shape {dims:?} needs {expect} elements, got {}",
            data.len()
        )));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(xla_err)
}

/// Scalar f32 literal.
pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read an f32 output literal into a Vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(xla_err)
}

/// Read an i32 output literal into a Vec.
pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(xla_err)
}
