//! Artifact registry: compile every manifest entry once, expose typed
//! call wrappers for each computation family.

use std::collections::HashMap;
use std::path::Path;

use super::manifest::{ArtifactMeta, Manifest};
use super::{execute_tuple, literal_f32, scalar_f32, to_vec_f32, to_vec_i32, PjrtRuntime};
use crate::error::{AsnnError, Result};

/// One compiled artifact plus its metadata.
pub struct CompiledArtifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// Output of a `disk_count` call for one query.
#[derive(Debug, Clone, PartialEq)]
pub struct DiskCountOut {
    /// Per-class point counts inside the circle.
    pub class_counts: Vec<f32>,
    /// Total count (sum of class counts, computed in-graph).
    pub total: f32,
    /// Eq. 1 next radius, computed in-graph.
    pub next_r: f32,
}

/// Output of a `neighbor_scan` call: top-K occupied pixels by distance.
#[derive(Debug, Clone, PartialEq)]
pub struct NeighborScanOut {
    /// Pixel-space distance per hit (L2: squared; +inf padding for
    /// absent hits).
    pub dists: Vec<f32>,
    /// Flattened window pixel index per hit (-1 padding).
    pub indices: Vec<i32>,
}

/// Output of a `knn_chunk` call: per-query top-K over one point chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct KnnChunkOut {
    /// `[batch × k_max]` squared distances (+inf padding).
    pub dists: Vec<f32>,
    /// `[batch × k_max]` point indices within the chunk (-1 padding).
    pub indices: Vec<i32>,
}

impl CompiledArtifact {
    /// Call a `disk_count` artifact (batch 1). `window` is `[C, W, W]`
    /// row-major; the circle center is the window center.
    pub fn disk_count(&self, window: &[f32], r: f32, k: f32, metric_l1: bool) -> Result<DiskCountOut> {
        let m = &self.meta;
        if m.kind != "disk_count" || m.batch != 1 {
            return Err(AsnnError::Runtime(format!(
                "{} is not a batch-1 disk_count artifact",
                m.name
            )));
        }
        let w = m.window as i64;
        let c = m.classes as i64;
        let win = literal_f32(window, &[c, w, w])?;
        let outs = execute_tuple(
            &self.exe,
            &[win, scalar_f32(r), scalar_f32(k), scalar_f32(if metric_l1 { 1.0 } else { 0.0 })],
        )?;
        if outs.len() != 3 {
            return Err(AsnnError::Runtime(format!(
                "disk_count returned {} outputs, expected 3",
                outs.len()
            )));
        }
        Ok(DiskCountOut {
            class_counts: to_vec_f32(&outs[0])?,
            total: to_vec_f32(&outs[1])?[0],
            next_r: to_vec_f32(&outs[2])?[0],
        })
    }

    /// Call a batched `disk_count` artifact: `windows` is `[B, C, W, W]`,
    /// `rs` is `[B]`. Returns per-query outputs.
    pub fn disk_count_batch(
        &self,
        windows: &[f32],
        rs: &[f32],
        k: f32,
        metric_l1: bool,
    ) -> Result<Vec<DiskCountOut>> {
        let m = &self.meta;
        if m.kind != "disk_count" {
            return Err(AsnnError::Runtime(format!("{} is not disk_count", m.name)));
        }
        let (b, c, w) = (m.batch as i64, m.classes as i64, m.window as i64);
        if rs.len() != m.batch {
            return Err(AsnnError::Runtime(format!(
                "batch artifact {} expects {} radii, got {}",
                m.name,
                m.batch,
                rs.len()
            )));
        }
        let win = literal_f32(windows, &[b, c, w, w])?;
        let rlit = literal_f32(rs, &[b])?;
        let outs = execute_tuple(
            &self.exe,
            &[win, rlit, scalar_f32(k), scalar_f32(if metric_l1 { 1.0 } else { 0.0 })],
        )?;
        let class_counts = to_vec_f32(&outs[0])?; // [B, C]
        let totals = to_vec_f32(&outs[1])?; // [B]
        let next_rs = to_vec_f32(&outs[2])?; // [B]
        Ok((0..m.batch)
            .map(|i| DiskCountOut {
                class_counts: class_counts[i * m.classes..(i + 1) * m.classes].to_vec(),
                total: totals[i],
                next_r: next_rs[i],
            })
            .collect())
    }

    /// Call a `neighbor_scan` artifact: total-count window `[W, W]`,
    /// radius, metric flag → top-K occupied pixels.
    pub fn neighbor_scan(&self, window: &[f32], r: f32, metric_l1: bool) -> Result<NeighborScanOut> {
        let m = &self.meta;
        if m.kind != "neighbor_scan" {
            return Err(AsnnError::Runtime(format!("{} is not neighbor_scan", m.name)));
        }
        let w = m.window as i64;
        let win = literal_f32(window, &[w, w])?;
        let outs = execute_tuple(
            &self.exe,
            &[win, scalar_f32(r), scalar_f32(if metric_l1 { 1.0 } else { 0.0 })],
        )?;
        Ok(NeighborScanOut { dists: to_vec_f32(&outs[0])?, indices: to_vec_i32(&outs[1])? })
    }

    /// Call a `knn_chunk` artifact: queries `[B, 2]`, chunk `[N, 2]`,
    /// `valid` = live prefix length of the chunk (rest is padding).
    pub fn knn_chunk(&self, queries: &[f32], chunk: &[f32], valid: usize) -> Result<KnnChunkOut> {
        let m = &self.meta;
        if m.kind != "knn_chunk" {
            return Err(AsnnError::Runtime(format!("{} is not knn_chunk", m.name)));
        }
        let q = literal_f32(queries, &[m.batch as i64, 2])?;
        let c = literal_f32(chunk, &[m.chunk as i64, 2])?;
        let outs = execute_tuple(&self.exe, &[q, c, scalar_f32(valid as f32)])?;
        Ok(KnnChunkOut { dists: to_vec_f32(&outs[0])?, indices: to_vec_i32(&outs[1])? })
    }
}

/// All compiled artifacts, keyed by manifest name.
pub struct ArtifactRegistry {
    pub manifest: Manifest,
    map: HashMap<String, CompiledArtifact>,
}

impl ArtifactRegistry {
    /// Compile every manifest entry (one-time cost at startup). The
    /// manifest's files are integrity-checked first so a torn `make
    /// artifacts` (missing or zero-byte HLO file) fails here with the
    /// offending entry named, not deep inside the XLA compiler.
    pub fn load(rt: &PjrtRuntime, dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        manifest.check_files()?;
        let mut map = HashMap::new();
        for meta in manifest.iter() {
            let path = manifest.path_of(meta);
            let exe = rt.compile_file(&path).map_err(|e| {
                AsnnError::Runtime(format!("compiling {}: {e}", path.display()))
            })?;
            map.insert(meta.name.clone(), CompiledArtifact { meta: meta.clone(), exe });
        }
        Ok(Self { manifest, map })
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&CompiledArtifact> {
        self.map.get(name)
    }

    /// The disk_count artifact for a given window size and batch.
    pub fn disk_count_for(&self, window: usize, batch: usize) -> Option<&CompiledArtifact> {
        self.get(&format!("disk_count_w{window}_b{batch}"))
    }

    /// The neighbor_scan artifact for a window size.
    pub fn neighbor_scan_for(&self, window: usize) -> Option<&CompiledArtifact> {
        self.get(&format!("neighbor_scan_w{window}"))
    }

    /// The knn_chunk artifact for a batch size.
    pub fn knn_chunk_for(&self, batch: usize) -> Option<&CompiledArtifact> {
        self.get(&format!("knn_chunk_b{batch}"))
    }

    /// Window sizes available for batch-1 disk_count, ascending.
    pub fn disk_count_windows(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .map
            .values()
            .filter(|a| a.meta.kind == "disk_count" && a.meta.batch == 1)
            .map(|a| a.meta.window)
            .collect();
        v.sort_unstable();
        v
    }
}
