//! Artifact manifest: `artifacts/manifest.toml`, written by
//! `python/compile/aot.py` and parsed with the in-repo TOML subset.
//!
//! One section per artifact:
//!
//! ```toml
//! [disk_count_w64_b1]
//! kind = "disk_count"
//! file = "disk_count_w64_b1.hlo.txt"
//! window = 64
//! batch = 1
//! classes = 3
//! k_max = 32
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{AsnnError, Result};
use crate::util::toml::Document;

/// Metadata for one AOT artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    /// Computation family: `disk_count`, `neighbor_scan`, `knn_chunk`.
    pub kind: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Static window side (0 when not applicable).
    pub window: usize,
    /// Static batch size.
    pub batch: usize,
    /// Number of class channels baked into the shapes.
    pub classes: usize,
    /// Static top-k width (0 when not applicable).
    pub k_max: usize,
    /// Static chunk length for `knn_chunk` (0 otherwise).
    pub chunk: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    entries: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.toml`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.toml");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            AsnnError::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        // The TOML-subset parser merges duplicate [section] headers
        // silently, which for a manifest means one artifact's shape
        // metadata clobbers another's. Detect duplicates on the raw
        // text before parsing.
        let mut seen = std::collections::BTreeSet::new();
        for line in text.lines() {
            let line = line.trim();
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                if !seen.insert(name.trim().to_string()) {
                    return Err(AsnnError::Runtime(format!(
                        "duplicate manifest entry {:?}",
                        name.trim()
                    )));
                }
            }
        }
        let doc = Document::parse(text)?;
        let mut entries = BTreeMap::new();
        for name in doc.sections() {
            if name.is_empty() {
                continue; // top-level keys (e.g. generator version) ignored
            }
            let kind = doc.str_or(name, "kind", "");
            let file = doc.str_or(name, "file", "");
            if kind.is_empty() || file.is_empty() {
                return Err(AsnnError::Runtime(format!(
                    "manifest entry {name:?} missing kind/file"
                )));
            }
            validate_file_path(name, &file)?;
            entries.insert(
                name.to_string(),
                ArtifactMeta {
                    name: name.to_string(),
                    kind,
                    file,
                    window: doc.int_or(name, "window", 0) as usize,
                    batch: doc.int_or(name, "batch", 1) as usize,
                    classes: doc.int_or(name, "classes", 0) as usize,
                    k_max: doc.int_or(name, "k_max", 0) as usize,
                    chunk: doc.int_or(name, "chunk", 0) as usize,
                },
            );
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.get(name)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.entries.values()
    }

    /// All entries of a kind, sorted by (window, batch).
    pub fn of_kind(&self, kind: &str) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> =
            self.entries.values().filter(|m| m.kind == kind).collect();
        v.sort_by_key(|m| (m.window, m.batch));
        v
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Verify every referenced HLO file exists and is non-empty. A
    /// zero-byte artifact is the residue of an interrupted `make
    /// artifacts`; compiling it would fail confusingly much later.
    pub fn check_files(&self) -> Result<()> {
        for meta in self.entries.values() {
            let path = self.path_of(meta);
            let md = std::fs::metadata(&path).map_err(|e| {
                AsnnError::Runtime(format!(
                    "artifact {:?}: cannot stat {}: {e}",
                    meta.name,
                    path.display()
                ))
            })?;
            if md.len() == 0 {
                return Err(AsnnError::Runtime(format!(
                    "artifact {:?}: {} is zero bytes (torn write?)",
                    meta.name,
                    path.display()
                )));
            }
        }
        Ok(())
    }
}

/// Reject `file` values that resolve outside the manifest directory —
/// a manifest is data, not a license to read anywhere on disk.
fn validate_file_path(name: &str, file: &str) -> Result<()> {
    use std::path::Component;
    let p = Path::new(file);
    for comp in p.components() {
        match comp {
            Component::ParentDir => {
                return Err(AsnnError::Runtime(format!(
                    "manifest entry {name:?}: file {file:?} escapes the manifest dir"
                )));
            }
            Component::RootDir | Component::Prefix(_) => {
                return Err(AsnnError::Runtime(format!(
                    "manifest entry {name:?}: file {file:?} must be relative"
                )));
            }
            _ => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        version = 1
        [disk_count_w64_b1]
        kind = "disk_count"
        file = "disk_count_w64_b1.hlo.txt"
        window = 64
        batch = 1
        classes = 3
        [disk_count_w128_b1]
        kind = "disk_count"
        file = "disk_count_w128_b1.hlo.txt"
        window = 128
        batch = 1
        classes = 3
        [knn_chunk_b16]
        kind = "knn_chunk"
        file = "knn_chunk_b16.hlo.txt"
        batch = 16
        chunk = 4096
        k_max = 32
    "#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.len(), 3);
        let e = m.get("disk_count_w64_b1").unwrap();
        assert_eq!(e.kind, "disk_count");
        assert_eq!(e.window, 64);
        assert_eq!(e.classes, 3);
        assert_eq!(e.batch, 1);
    }

    #[test]
    fn of_kind_sorted_by_window() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let dc = m.of_kind("disk_count");
        assert_eq!(dc.len(), 2);
        assert!(dc[0].window < dc[1].window);
    }

    #[test]
    fn path_joins_dir() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let e = m.get("knn_chunk_b16").unwrap();
        assert_eq!(m.path_of(e), Path::new("/tmp/a/knn_chunk_b16.hlo.txt"));
        assert_eq!(e.chunk, 4096);
        assert_eq!(e.k_max, 32);
    }

    #[test]
    fn missing_fields_rejected() {
        let bad = "[x]\nwindow = 3";
        assert!(Manifest::parse(Path::new("/tmp"), bad).is_err());
    }

    #[test]
    fn top_level_keys_ignored() {
        let m = Manifest::parse(Path::new("/tmp"), "version = 2").unwrap();
        assert!(m.is_empty());
    }

    #[test]
    fn duplicate_entries_rejected() {
        let bad = r#"
            [a]
            kind = "disk_count"
            file = "a.hlo.txt"
            [a]
            kind = "disk_count"
            file = "other.hlo.txt"
        "#;
        let err = Manifest::parse(Path::new("/tmp"), bad).unwrap_err().to_string();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn escaping_paths_rejected() {
        for file in ["../../../etc/passwd", "ok/../../up", "/etc/passwd"] {
            let text = format!("[a]\nkind = \"disk_count\"\nfile = \"{file}\"\n");
            let err = Manifest::parse(Path::new("/tmp"), &text).unwrap_err().to_string();
            assert!(
                err.contains("escapes") || err.contains("relative"),
                "{file}: {err}"
            );
        }
        // plain subdirectory paths stay allowed
        let ok = "[a]\nkind = \"disk_count\"\nfile = \"sub/a.hlo.txt\"\n";
        assert!(Manifest::parse(Path::new("/tmp"), ok).is_ok());
    }

    #[test]
    fn check_files_rejects_missing_and_zero_byte() {
        let mut dir = std::env::temp_dir();
        dir.push(format!("asnn-manifest-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let text = "[a]\nkind = \"disk_count\"\nfile = \"a.hlo.txt\"\n";
        let m = Manifest::parse(&dir, text).unwrap();

        // missing
        let err = m.check_files().unwrap_err().to_string();
        assert!(err.contains("cannot stat"), "{err}");

        // zero-byte (torn write)
        std::fs::write(dir.join("a.hlo.txt"), b"").unwrap();
        let err = m.check_files().unwrap_err().to_string();
        assert!(err.contains("zero bytes"), "{err}");

        // real content passes
        std::fs::write(dir.join("a.hlo.txt"), b"HloModule m").unwrap();
        m.check_files().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
