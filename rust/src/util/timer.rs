//! Timing helpers for benches and coordinator metrics.

use std::time::{Duration, Instant};

/// Scope timer: measures elapsed wall time since construction.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ns(&self) -> u64 {
        self.elapsed().as_nanos() as u64
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::new();
    let out = f();
    (out, t.elapsed_secs())
}

/// Human-readable duration (ns/µs/ms/s picked by magnitude).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::new();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ns() >= 1_000_000);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, s) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn fmt_magnitudes() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with('s'));
    }
}
