//! Minimal TOML-subset parser for the config system.
//!
//! Supported: `[section]` headers, `key = value` with string ("..."),
//! integer, float, boolean, and flat arrays of those; `#` comments;
//! blank lines. This covers the full config surface of `asnn.toml`
//! without pulling a parser crate into the offline build.

use std::collections::BTreeMap;

use crate::error::{AsnnError, Result};

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: section name → (key → value). Top-level keys live
/// under the empty section name `""`.
#[derive(Debug, Clone, Default)]
pub struct Document {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = Document::default();
        let mut current = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| {
                    AsnnError::Config(format!("line {}: unterminated section header", lineno + 1))
                })?;
                current = name.trim().to_string();
                doc.sections.entry(current.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim().to_string();
                let val = parse_value(v.trim(), lineno + 1)?;
                doc.sections.entry(current.clone()).or_default().insert(key, val);
            } else {
                return Err(AsnnError::Config(format!(
                    "line {}: expected `key = value` or `[section]`, got {line:?}",
                    lineno + 1
                )));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // a `#` inside a quoted string must not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str, lineno: usize) -> Result<Value> {
    let err = |msg: String| AsnnError::Config(format!("line {lineno}: {msg}"));
    if raw.is_empty() {
        return Err(err("empty value".into()));
    }
    if let Some(inner) = raw.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| err(format!("unterminated string {raw:?}")))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = raw.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(format!("unterminated array {raw:?}")))?;
        let mut vals = Vec::new();
        let inner = inner.trim();
        if !inner.is_empty() {
            for part in split_top_level(inner) {
                vals.push(parse_value(part.trim(), lineno)?);
            }
        }
        return Ok(Value::Array(vals));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(format!("cannot parse value {raw:?}")))
}

/// Split an array body on commas that are not inside quotes.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Document::parse(
            r#"
            top = 1
            [data]
            n = 10000            # points
            seed = 42
            classes = 3
            name = "paper-2d"
            fractions = [0.5, 0.25, 0.25]
            [search]
            metric = "l2"
            refine = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.int_or("", "top", 0), 1);
        assert_eq!(doc.int_or("data", "n", 0), 10_000);
        assert_eq!(doc.str_or("data", "name", ""), "paper-2d");
        assert!(doc.bool_or("search", "refine", false));
        let arr = doc.get("data", "fractions").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert!((arr[0].as_float().unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = Document::parse(r##"k = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.str_or("", "k", ""), "a#b");
    }

    #[test]
    fn error_on_garbage() {
        assert!(Document::parse("not a kv line").is_err());
        assert!(Document::parse("[unterminated").is_err());
        assert!(Document::parse("x = ").is_err());
        assert!(Document::parse("x = \"oops").is_err());
        assert!(Document::parse("x = [1, 2").is_err());
    }

    #[test]
    fn defaults_apply() {
        let doc = Document::parse("[a]\nx = 1").unwrap();
        assert_eq!(doc.int_or("a", "missing", 9), 9);
        assert_eq!(doc.float_or("a", "x", 0.0), 1.0); // int promotes to float
    }

    #[test]
    fn empty_array() {
        let doc = Document::parse("xs = []").unwrap();
        assert_eq!(doc.get("", "xs").unwrap().as_array().unwrap().len(), 0);
    }
}
