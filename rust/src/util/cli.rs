//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands. Typed getters parse on demand and report readable errors.

use std::collections::BTreeMap;

use crate::error::{AsnnError, Result};

/// Parsed arguments: a subcommand (first positional before any flag),
/// remaining positionals, and `--key [value]` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // value-style if next token exists and is not an option
                    let takes_value =
                        matches!(it.peek(), Some(nxt) if !nxt.starts_with("--"));
                    if takes_value {
                        let v = it.next().unwrap();
                        out.options.insert(stripped.to_string(), v);
                    } else {
                        out.flags.push(stripped.to_string());
                    }
                }
            } else if out.subcommand.is_none() && out.positionals.is_empty() && out.options.is_empty() && out.flags.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    fn parse_as<T: std::str::FromStr>(&self, name: &str, raw: &str) -> Result<T> {
        raw.parse::<T>().map_err(|_| {
            AsnnError::Config(format!(
                "option --{name}: cannot parse {raw:?} as {}",
                std::any::type_name::<T>()
            ))
        })
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(raw) => self.parse_as(name, raw),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(raw) => self.parse_as(name, raw),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(raw) => self.parse_as(name, raw),
            None => Ok(default),
        }
    }

    /// Required string option.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| AsnnError::Config(format!("missing required option --{name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("bench --n 1000 --engine=active --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("n"), Some("1000"));
        assert_eq!(a.get("engine"), Some("active"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("run --k 11 --r0 100 --frac 0.5");
        assert_eq!(a.get_usize("k", 3).unwrap(), 11);
        assert_eq!(a.get_u64("r0", 1).unwrap(), 100);
        assert!((a.get_f64("frac", 0.0).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_parse_is_config_error() {
        let a = parse("run --k eleven");
        assert!(matches!(a.get_usize("k", 3), Err(AsnnError::Config(_))));
    }

    #[test]
    fn require_missing() {
        let a = parse("run");
        assert!(a.require("out").is_err());
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("serve --quiet");
        assert!(a.has_flag("quiet"));
        assert_eq!(a.get("quiet"), None);
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = parse("viz fig1 fig2 --out dir");
        assert_eq!(a.subcommand.as_deref(), Some("viz"));
        assert_eq!(a.positionals, vec!["fig1", "fig2"]);
        assert_eq!(a.get("out"), Some("dir"));
    }
}
