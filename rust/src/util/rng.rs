//! Deterministic PRNG: SplitMix64 seeding + Xoshiro256++ generation.
//!
//! The `rand` crate is not in the offline vendor set, so we implement
//! the standard small-state generators. All experiment workloads are
//! seeded, making every bench and test reproducible bit-for-bit.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in `[0, 1)` using the top 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Split off an independent stream (for per-thread generators).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn split_streams_are_independent() {
        let mut base = Rng::new(42);
        let mut a = base.split();
        let mut b = base.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
