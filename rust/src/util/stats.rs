//! Statistics substrates used by the bench harness and the coordinator
//! metrics: Welford online moments, exact percentiles over samples, and
//! a fixed-bucket log-scale latency histogram.

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a sample set (linear interpolation, like
/// numpy's default). `q` in [0, 100].
pub fn percentile(samples: &mut [f64], q: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (samples.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        samples[lo]
    } else {
        let frac = rank - lo as f64;
        samples[lo] * (1.0 - frac) + samples[hi] * frac
    }
}

/// Log₂-bucketed latency histogram in nanoseconds. 64 buckets cover
/// 1 ns .. ~584 years; recording is lock-free-friendly (plain u64s —
/// callers wrap in a mutex or use one per thread and merge).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: [u64; 64],
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { buckets: [0; 64], count: 0, sum_ns: 0, max_ns: 0 }
    }

    #[inline]
    fn bucket_of(ns: u64) -> usize {
        (64 - ns.max(1).leading_zeros() as usize) - 1
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.sum_ns as f64 / self.count as f64 }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Approximate quantile: returns the upper edge of the bucket where
    /// the q-quantile falls (q in [0,1]). Error is bounded by 2× (one
    /// log₂ bucket).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        for (i, &b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return 1u64 << (i + 1).min(63);
            }
        }
        self.max_ns
    }

    /// Merge another histogram into this one (for per-thread merging).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for i in 0..64 {
            self.buckets[i] += other.buckets[i];
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic set is 32/7
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn welford_empty_is_nan() {
        let w = Welford::new();
        assert!(w.mean().is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let mut xs = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 100.0), 4.0);
        assert!((percentile(&mut xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_is_nan() {
        assert!(percentile(&mut [], 50.0).is_nan());
    }

    #[test]
    fn histogram_buckets() {
        assert_eq!(LatencyHistogram::bucket_of(1), 0);
        assert_eq!(LatencyHistogram::bucket_of(2), 1);
        assert_eq!(LatencyHistogram::bucket_of(3), 1);
        assert_eq!(LatencyHistogram::bucket_of(1024), 10);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 100);
        }
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p99);
        assert!(h.mean_ns() > 0.0);
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn histogram_merge_adds() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_ns(10);
        b.record_ns(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_ns(), 1_000_000);
    }
}
