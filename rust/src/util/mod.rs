//! Zero-dependency substrates: PRNG, CLI parsing, statistics, a minimal
//! TOML-subset parser, and timing helpers.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (`rand`,
//! `clap`, `serde`, `criterion`) are re-implemented here at the scale
//! this project needs.

pub mod cli;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod toml;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Clamp `v` into `[lo, hi]`.
#[inline]
pub fn clamp_i64(v: i64, lo: i64, hi: i64) -> i64 {
    v.max(lo).min(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn clamp_basics() {
        assert_eq!(clamp_i64(-5, 0, 10), 0);
        assert_eq!(clamp_i64(5, 0, 10), 5);
        assert_eq!(clamp_i64(50, 0, 10), 10);
    }
}
