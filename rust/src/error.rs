//! Crate-wide error type.
//!
//! Every public fallible API in `asnn` returns [`Result`]. Variants are
//! grouped by subsystem so callers can match on failure domains (config
//! vs. data vs. runtime) without string inspection.

use thiserror::Error;

/// Crate-wide error enum.
#[derive(Debug, Error)]
pub enum AsnnError {
    /// Configuration file / value errors (parse location included).
    #[error("config error: {0}")]
    Config(String),

    /// Dataset construction, I/O, or shape errors.
    #[error("data error: {0}")]
    Data(String),

    /// Grid/index construction errors (resolution, bounds, dimension).
    #[error("grid error: {0}")]
    Grid(String),

    /// Query-time errors (bad k, point outside bounds, engine misuse).
    #[error("query error: {0}")]
    Query(String),

    /// PJRT runtime errors (artifact load/compile/execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator / server / protocol errors.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Wire-protocol parse errors (malformed client request).
    #[error("protocol error: {0}")]
    Protocol(String),

    /// Server at capacity: request shed by admission control. Clients
    /// should back off and retry.
    #[error("overloaded: {0}")]
    Overloaded(String),

    /// Per-request deadline exceeded (the engine kept running; the
    /// response was abandoned).
    #[error("timeout: {0}")]
    Timeout(String),

    /// Durable-store failures: torn/corrupt snapshot files, checksum
    /// mismatches, framing violations. Distinct from [`Io`](Self::Io)
    /// so recovery code can tell "disk said no" from "file is garbage".
    #[error("store error: {0}")]
    Store(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, AsnnError>;

impl AsnnError {
    /// Short machine-readable tag used by the wire protocol.
    pub fn tag(&self) -> &'static str {
        match self {
            AsnnError::Config(_) => "config",
            AsnnError::Data(_) => "data",
            AsnnError::Grid(_) => "grid",
            AsnnError::Query(_) => "query",
            AsnnError::Runtime(_) => "runtime",
            AsnnError::Coordinator(_) => "coordinator",
            AsnnError::Protocol(_) => "protocol",
            AsnnError::Overloaded(_) => "overload",
            AsnnError::Timeout(_) => "timeout",
            AsnnError::Store(_) => "store",
            AsnnError::Io(_) => "io",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_domain() {
        let e = AsnnError::Grid("resolution must be > 0".into());
        assert!(e.to_string().contains("grid error"));
        assert_eq!(e.tag(), "grid");
    }

    #[test]
    fn io_error_converts() {
        fn fails() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "nope"))?;
            Ok(())
        }
        assert!(matches!(fails(), Err(AsnnError::Io(_))));
    }

    #[test]
    fn tags_are_distinct() {
        let tags = [
            AsnnError::Config(String::new()).tag(),
            AsnnError::Data(String::new()).tag(),
            AsnnError::Grid(String::new()).tag(),
            AsnnError::Query(String::new()).tag(),
            AsnnError::Runtime(String::new()).tag(),
            AsnnError::Coordinator(String::new()).tag(),
            AsnnError::Protocol(String::new()).tag(),
            AsnnError::Overloaded(String::new()).tag(),
            AsnnError::Timeout(String::new()).tag(),
            AsnnError::Store(String::new()).tag(),
        ];
        let mut uniq = tags.to_vec();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), tags.len());
    }
}
