//! Typed configuration for the whole stack, loaded from a TOML subset
//! (see [`crate::util::toml`]). Defaults reproduce the paper's §3 setup:
//! uniform 2-D points, 3 classes, 3000×3000 image, k = 11, r₀ = 100.

use std::path::Path;

use crate::data::synthetic::Family;
use crate::error::{AsnnError, Result};
use crate::util::toml::Document;

/// Distance metric used inside the scan circle (paper §3 discusses the
/// L1 variant as a cheaper approximation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    L2,
    L1,
}

impl Metric {
    pub fn parse(s: &str) -> Option<Metric> {
        match s {
            "l2" | "L2" | "euclidean" => Some(Metric::L2),
            "l1" | "L1" | "manhattan" => Some(Metric::L1),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::L1 => "l1",
        }
    }
}

/// How neighbors are returned by the active engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMode {
    /// Paper behaviour: pixel-level result — points in the final circle.
    Approx,
    /// Extension: re-rank candidate pixels by true point distance and
    /// return exact neighbor identities when possible.
    Refined,
}

impl SearchMode {
    pub fn parse(s: &str) -> Option<SearchMode> {
        match s {
            "approx" => Some(SearchMode::Approx),
            "refined" => Some(SearchMode::Refined),
            _ => None,
        }
    }
}

/// Initial-radius policy (ABL-R0 studies this; paper fixes r₀ = 100).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum R0Policy {
    /// Fixed pixel radius, the paper's choice.
    Fixed,
    /// Estimate from global density: r₀ = sqrt(k / (N / R²)) pixels.
    Density,
}

impl R0Policy {
    pub fn parse(s: &str) -> Option<R0Policy> {
        match s {
            "fixed" => Some(R0Policy::Fixed),
            "density" => Some(R0Policy::Density),
            _ => None,
        }
    }
}

/// Which engine serves queries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Brute,
    KdTree,
    Lsh,
    Active,
    ActivePjrt,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "brute" => Some(EngineKind::Brute),
            "kdtree" | "kd" => Some(EngineKind::KdTree),
            "lsh" => Some(EngineKind::Lsh),
            "active" => Some(EngineKind::Active),
            "active-pjrt" | "active_pjrt" | "pjrt" => Some(EngineKind::ActivePjrt),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Brute => "brute",
            EngineKind::KdTree => "kdtree",
            EngineKind::Lsh => "lsh",
            EngineKind::Active => "active",
            EngineKind::ActivePjrt => "active-pjrt",
        }
    }
}

/// `[data]` section.
#[derive(Debug, Clone)]
pub struct DataConfig {
    pub family: Family,
    pub n: usize,
    pub dim: usize,
    pub num_classes: usize,
    pub seed: u64,
}

/// `[grid]` section.
#[derive(Debug, Clone)]
pub struct GridConfig {
    /// Image side length in pixels (paper: 3000).
    pub resolution: usize,
    /// Fractional padding added around the data bounding box so fresh
    /// queries near the hull still land inside the image.
    pub padding: f64,
}

/// `[search]` section.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub k: usize,
    pub r0: u32,
    pub max_iters: u32,
    pub metric: Metric,
    pub mode: SearchMode,
    pub r0_policy: R0Policy,
    /// Accept |n_t − k| ≤ tolerance instead of exact equality (the paper
    /// requires n_t == k; tolerance 0 reproduces that).
    pub tolerance: u32,
    /// Skip the coarse candidate-count pass when the window is small
    /// enough to scan directly (see `docs/PERFORMANCE.md` for the
    /// ablation; off by default).
    pub coarse_skip: bool,
}

/// `[server]` section.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub workers: usize,
    pub batch_max: usize,
    pub batch_deadline_us: u64,
    /// Threads in the dedicated batch fan-out pool (kept separate from
    /// `workers`, the connection pool, to avoid queueing batch chunks
    /// behind the very connections that submitted them).
    pub batch_workers: usize,
}

/// `[runtime]` section.
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    pub artifacts_dir: String,
    /// Static window sizes the AOT artifacts were lowered for.
    pub window_sizes: Vec<usize>,
}

/// `[resilience]` section — failure handling in the serving harness
/// (see `coordinator::resilience`).
#[derive(Debug, Clone)]
pub struct ResilienceConfig {
    /// Per-attempt engine deadline in milliseconds; 0 disables the
    /// deadline guard (engine calls then run inline).
    pub deadline_ms: u64,
    /// Whole-request deadline budget in milliseconds: retries, backoff
    /// sleeps, and fallback hops all draw from this one budget.
    /// 0 disables budgeting.
    pub budget_ms: u64,
    /// How long a request waits for its current engine before hedging
    /// the same query at the next healthy fallback engine; 0 disables
    /// hedging.
    pub hedge_delay_ms: u64,
    /// Admitted-but-unfinished connection limit before the server
    /// sheds with `ERR overload`; 0 = unlimited.
    pub max_inflight: usize,
    /// Retries per engine attempt for transient failures.
    pub retry_max: u32,
    /// Base backoff before the first retry (doubles per retry).
    pub retry_backoff_us: u64,
    /// Consecutive failures that trip an engine's circuit breaker.
    pub breaker_threshold: u32,
    /// Open-breaker cooldown before a half-open probe.
    pub breaker_cooldown_ms: u64,
    /// Consecutive half-open probe successes required before an open
    /// breaker closes again (guards against flapping engines).
    pub probe_successes: u32,
    /// How long shutdown waits for in-flight connections to finish
    /// before force-closing them.
    pub drain_deadline_ms: u64,
    /// Whether engine failures fall through the fallback chain.
    pub fallback: bool,
    /// Socket read timeout in milliseconds; also the poll interval at
    /// which idle connections observe shutdown, so keep it small.
    pub read_timeout_ms: u64,
    /// Socket write timeout in milliseconds; a client that stops
    /// reading its responses is disconnected after this long.
    pub write_timeout_ms: u64,
    /// Disconnect a connection that has not completed a request line
    /// for this long (slow-loris defense); 0 disables the deadline.
    pub idle_timeout_ms: u64,
    /// Maximum request line length in bytes; longer lines get a
    /// structured `ERR too-long` and the connection closes.
    pub max_line_bytes: usize,
}

/// `[obs]` section — observability layer (see `crate::obs` and
/// `docs/OBSERVABILITY.md`).
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Whether the serving stack attaches a shared recorder (per-stage
    /// histograms, per-engine counters, STATS2/TRACE support data).
    /// Disabling leaves the verbs functional but empty of stage data.
    pub enabled: bool,
    /// Period between observability snapshot exports to the `[store]`
    /// directory in milliseconds; 0 disables periodic export (boot
    /// restore of a previous export still runs).
    pub export_interval_ms: u64,
}

/// `[store]` section — crash-safe snapshot persistence
/// (see `crate::store`).
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Directory for snapshot generations; empty string disables
    /// persistence entirely (no recovery pass, no periodic snapshots).
    pub dir: String,
    /// Period between background snapshots in milliseconds; 0 disables
    /// the periodic snapshotter (recovery at boot still runs).
    pub snapshot_interval_ms: u64,
    /// Snapshot generations retained per prefix; older ones are pruned
    /// after each successful save.
    pub keep: usize,
}

/// Top-level config.
#[derive(Debug, Clone)]
pub struct AsnnConfig {
    pub data: DataConfig,
    pub grid: GridConfig,
    pub search: SearchConfig,
    pub engine: EngineKind,
    pub server: ServerConfig,
    pub runtime: RuntimeConfig,
    pub resilience: ResilienceConfig,
    pub store: StoreConfig,
    pub obs: ObsConfig,
}

impl Default for AsnnConfig {
    fn default() -> Self {
        Self {
            data: DataConfig {
                family: Family::Uniform,
                n: 10_000,
                dim: 2,
                num_classes: 3,
                seed: 42,
            },
            grid: GridConfig { resolution: 3000, padding: 0.0 },
            search: SearchConfig {
                k: 11,
                r0: 100,
                max_iters: 64,
                metric: Metric::L2,
                mode: SearchMode::Refined,
                r0_policy: R0Policy::Fixed,
                tolerance: 0,
                coarse_skip: false,
            },
            engine: EngineKind::Active,
            server: ServerConfig {
                addr: "127.0.0.1:7878".into(),
                workers: 2,
                batch_max: 16,
                batch_deadline_us: 200,
                batch_workers: 2,
            },
            runtime: RuntimeConfig {
                artifacts_dir: "artifacts".into(),
                window_sizes: vec![64, 128, 256, 512],
            },
            resilience: ResilienceConfig {
                deadline_ms: 0,
                budget_ms: 0,
                hedge_delay_ms: 0,
                max_inflight: 1024,
                retry_max: 1,
                retry_backoff_us: 500,
                breaker_threshold: 5,
                breaker_cooldown_ms: 1000,
                probe_successes: 1,
                drain_deadline_ms: 500,
                fallback: true,
                read_timeout_ms: 100,
                write_timeout_ms: 100,
                idle_timeout_ms: 30_000,
                max_line_bytes: 64 * 1024,
            },
            store: StoreConfig {
                dir: "state".into(),
                snapshot_interval_ms: 60_000,
                keep: 3,
            },
            obs: ObsConfig { enabled: true, export_interval_ms: 10_000 },
        }
    }
}

impl AsnnConfig {
    /// Load from a TOML file; unspecified keys keep their defaults.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text; unspecified keys keep their defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = Document::parse(text)?;
        let mut cfg = AsnnConfig::default();

        let fam = doc.str_or("data", "family", "uniform");
        cfg.data.family = Family::parse(&fam)
            .ok_or_else(|| AsnnError::Config(format!("unknown data.family {fam:?}")))?;
        cfg.data.n = doc.int_or("data", "n", cfg.data.n as i64) as usize;
        cfg.data.dim = doc.int_or("data", "dim", cfg.data.dim as i64) as usize;
        cfg.data.num_classes =
            doc.int_or("data", "classes", cfg.data.num_classes as i64) as usize;
        cfg.data.seed = doc.int_or("data", "seed", cfg.data.seed as i64) as u64;

        cfg.grid.resolution =
            doc.int_or("grid", "resolution", cfg.grid.resolution as i64) as usize;
        cfg.grid.padding = doc.float_or("grid", "padding", cfg.grid.padding);

        cfg.search.k = doc.int_or("search", "k", cfg.search.k as i64) as usize;
        cfg.search.r0 = doc.int_or("search", "r0", cfg.search.r0 as i64) as u32;
        cfg.search.max_iters =
            doc.int_or("search", "max_iters", cfg.search.max_iters as i64) as u32;
        cfg.search.tolerance =
            doc.int_or("search", "tolerance", cfg.search.tolerance as i64) as u32;
        cfg.search.coarse_skip =
            doc.bool_or("search", "coarse_skip", cfg.search.coarse_skip);
        let metric = doc.str_or("search", "metric", cfg.search.metric.name());
        cfg.search.metric = Metric::parse(&metric)
            .ok_or_else(|| AsnnError::Config(format!("unknown search.metric {metric:?}")))?;
        let mode = doc.str_or("search", "mode", "refined");
        cfg.search.mode = SearchMode::parse(&mode)
            .ok_or_else(|| AsnnError::Config(format!("unknown search.mode {mode:?}")))?;
        let pol = doc.str_or("search", "r0_policy", "fixed");
        cfg.search.r0_policy = R0Policy::parse(&pol)
            .ok_or_else(|| AsnnError::Config(format!("unknown search.r0_policy {pol:?}")))?;

        let engine = doc.str_or("engine", "kind", cfg.engine.name());
        cfg.engine = EngineKind::parse(&engine)
            .ok_or_else(|| AsnnError::Config(format!("unknown engine.kind {engine:?}")))?;

        cfg.server.addr = doc.str_or("server", "addr", &cfg.server.addr);
        cfg.server.workers =
            doc.int_or("server", "workers", cfg.server.workers as i64) as usize;
        cfg.server.batch_max =
            doc.int_or("server", "batch_max", cfg.server.batch_max as i64) as usize;
        cfg.server.batch_deadline_us =
            doc.int_or("server", "batch_deadline_us", cfg.server.batch_deadline_us as i64) as u64;
        cfg.server.batch_workers =
            doc.int_or("server", "batch_workers", cfg.server.batch_workers as i64) as usize;

        cfg.resilience.deadline_ms =
            doc.int_or("resilience", "deadline_ms", cfg.resilience.deadline_ms as i64) as u64;
        cfg.resilience.budget_ms =
            doc.int_or("resilience", "budget_ms", cfg.resilience.budget_ms as i64) as u64;
        cfg.resilience.hedge_delay_ms = doc.int_or(
            "resilience",
            "hedge_delay_ms",
            cfg.resilience.hedge_delay_ms as i64,
        ) as u64;
        cfg.resilience.max_inflight =
            doc.int_or("resilience", "max_inflight", cfg.resilience.max_inflight as i64)
                as usize;
        cfg.resilience.retry_max =
            doc.int_or("resilience", "retry_max", cfg.resilience.retry_max as i64) as u32;
        cfg.resilience.retry_backoff_us = doc.int_or(
            "resilience",
            "retry_backoff_us",
            cfg.resilience.retry_backoff_us as i64,
        ) as u64;
        cfg.resilience.breaker_threshold = doc.int_or(
            "resilience",
            "breaker_threshold",
            cfg.resilience.breaker_threshold as i64,
        ) as u32;
        cfg.resilience.breaker_cooldown_ms = doc.int_or(
            "resilience",
            "breaker_cooldown_ms",
            cfg.resilience.breaker_cooldown_ms as i64,
        ) as u64;
        cfg.resilience.probe_successes = doc.int_or(
            "resilience",
            "probe_successes",
            cfg.resilience.probe_successes as i64,
        ) as u32;
        cfg.resilience.drain_deadline_ms = doc.int_or(
            "resilience",
            "drain_deadline_ms",
            cfg.resilience.drain_deadline_ms as i64,
        ) as u64;
        cfg.resilience.fallback =
            doc.bool_or("resilience", "fallback", cfg.resilience.fallback);
        cfg.resilience.read_timeout_ms = doc.int_or(
            "resilience",
            "read_timeout_ms",
            cfg.resilience.read_timeout_ms as i64,
        ) as u64;
        cfg.resilience.write_timeout_ms = doc.int_or(
            "resilience",
            "write_timeout_ms",
            cfg.resilience.write_timeout_ms as i64,
        ) as u64;
        cfg.resilience.idle_timeout_ms = doc.int_or(
            "resilience",
            "idle_timeout_ms",
            cfg.resilience.idle_timeout_ms as i64,
        ) as u64;
        cfg.resilience.max_line_bytes = doc.int_or(
            "resilience",
            "max_line_bytes",
            cfg.resilience.max_line_bytes as i64,
        ) as usize;

        cfg.store.dir = doc.str_or("store", "dir", &cfg.store.dir);
        cfg.store.snapshot_interval_ms = doc.int_or(
            "store",
            "snapshot_interval_ms",
            cfg.store.snapshot_interval_ms as i64,
        ) as u64;
        cfg.store.keep = doc.int_or("store", "keep", cfg.store.keep as i64) as usize;

        cfg.obs.enabled = doc.bool_or("obs", "enabled", cfg.obs.enabled);
        cfg.obs.export_interval_ms = doc.int_or(
            "obs",
            "export_interval_ms",
            cfg.obs.export_interval_ms as i64,
        ) as u64;

        cfg.runtime.artifacts_dir =
            doc.str_or("runtime", "artifacts_dir", &cfg.runtime.artifacts_dir);
        if let Some(arr) = doc.get("runtime", "window_sizes").and_then(|v| v.as_array()) {
            cfg.runtime.window_sizes = arr
                .iter()
                .filter_map(|v| v.as_int())
                .map(|v| v as usize)
                .collect();
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<()> {
        if self.data.n == 0 {
            return Err(AsnnError::Config("data.n must be > 0".into()));
        }
        if self.data.dim < 2 {
            return Err(AsnnError::Config("data.dim must be >= 2".into()));
        }
        if self.data.num_classes == 0 {
            return Err(AsnnError::Config("data.classes must be > 0".into()));
        }
        if self.grid.resolution < 8 {
            return Err(AsnnError::Config("grid.resolution must be >= 8".into()));
        }
        if !(0.0..0.5).contains(&self.grid.padding) {
            return Err(AsnnError::Config("grid.padding must be in [0, 0.5)".into()));
        }
        if self.search.k == 0 {
            return Err(AsnnError::Config("search.k must be > 0".into()));
        }
        if self.search.k >= self.data.n {
            return Err(AsnnError::Config(format!(
                "search.k ({}) must be < data.n ({})",
                self.search.k, self.data.n
            )));
        }
        if self.search.r0 == 0 {
            return Err(AsnnError::Config("search.r0 must be > 0".into()));
        }
        if self.search.max_iters == 0 {
            return Err(AsnnError::Config("search.max_iters must be > 0".into()));
        }
        if self.server.workers == 0 || self.server.batch_max == 0 {
            return Err(AsnnError::Config("server.workers/batch_max must be > 0".into()));
        }
        if self.server.batch_workers == 0 {
            return Err(AsnnError::Config("server.batch_workers must be > 0".into()));
        }
        if self.runtime.window_sizes.is_empty() {
            return Err(AsnnError::Config("runtime.window_sizes must be non-empty".into()));
        }
        if self.resilience.breaker_threshold == 0 {
            return Err(AsnnError::Config(
                "resilience.breaker_threshold must be > 0".into(),
            ));
        }
        if self.resilience.breaker_cooldown_ms == 0 {
            return Err(AsnnError::Config(
                "resilience.breaker_cooldown_ms must be > 0".into(),
            ));
        }
        if self.resilience.probe_successes == 0 {
            return Err(AsnnError::Config(
                "resilience.probe_successes must be > 0".into(),
            ));
        }
        if self.resilience.drain_deadline_ms == 0 {
            return Err(AsnnError::Config(
                "resilience.drain_deadline_ms must be > 0".into(),
            ));
        }
        if self.resilience.read_timeout_ms == 0 || self.resilience.write_timeout_ms == 0 {
            return Err(AsnnError::Config(
                "resilience.read_timeout_ms/write_timeout_ms must be > 0".into(),
            ));
        }
        if self.resilience.max_line_bytes < 64 {
            return Err(AsnnError::Config(
                "resilience.max_line_bytes must be >= 64".into(),
            ));
        }
        if self.store.keep == 0 {
            return Err(AsnnError::Config("store.keep must be >= 1".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_setup() {
        let c = AsnnConfig::default();
        assert_eq!(c.grid.resolution, 3000);
        assert_eq!(c.search.k, 11);
        assert_eq!(c.search.r0, 100);
        assert_eq!(c.data.num_classes, 3);
        assert_eq!(c.data.dim, 2);
        c.validate().unwrap();
    }

    #[test]
    fn toml_overrides() {
        let c = AsnnConfig::from_toml(
            r#"
            [data]
            family = "blobs"
            n = 5000
            [search]
            k = 5
            metric = "l1"
            mode = "approx"
            [engine]
            kind = "kdtree"
            [runtime]
            window_sizes = [32, 64]
            "#,
        )
        .unwrap();
        assert_eq!(c.data.family, Family::Blobs);
        assert_eq!(c.data.n, 5000);
        assert_eq!(c.search.k, 5);
        assert_eq!(c.search.metric, Metric::L1);
        assert_eq!(c.search.mode, SearchMode::Approx);
        assert_eq!(c.engine, EngineKind::KdTree);
        assert_eq!(c.runtime.window_sizes, vec![32, 64]);
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(AsnnConfig::from_toml("[search]\nk = 0").is_err());
        assert!(AsnnConfig::from_toml("[data]\nfamily = \"weird\"").is_err());
        assert!(AsnnConfig::from_toml("[search]\nmetric = \"l7\"").is_err());
        assert!(AsnnConfig::from_toml("[grid]\nresolution = 2").is_err());
        assert!(AsnnConfig::from_toml("[data]\nn = 5\n[search]\nk = 11").is_err());
        assert!(AsnnConfig::from_toml("[resilience]\nbreaker_threshold = 0").is_err());
        assert!(AsnnConfig::from_toml("[resilience]\nbreaker_cooldown_ms = 0").is_err());
        assert!(AsnnConfig::from_toml("[resilience]\nprobe_successes = 0").is_err());
        assert!(AsnnConfig::from_toml("[resilience]\ndrain_deadline_ms = 0").is_err());
        assert!(AsnnConfig::from_toml("[resilience]\nread_timeout_ms = 0").is_err());
        assert!(AsnnConfig::from_toml("[resilience]\nwrite_timeout_ms = 0").is_err());
        assert!(AsnnConfig::from_toml("[resilience]\nmax_line_bytes = 10").is_err());
        assert!(AsnnConfig::from_toml("[store]\nkeep = 0").is_err());
    }

    #[test]
    fn wire_limit_and_store_defaults_and_overrides() {
        let c = AsnnConfig::default();
        assert_eq!(c.resilience.read_timeout_ms, 100);
        assert_eq!(c.resilience.write_timeout_ms, 100);
        assert_eq!(c.resilience.idle_timeout_ms, 30_000);
        assert_eq!(c.resilience.max_line_bytes, 64 * 1024);
        assert_eq!(c.store.dir, "state");
        assert_eq!(c.store.snapshot_interval_ms, 60_000);
        assert_eq!(c.store.keep, 3);
        c.validate().unwrap();

        let c = AsnnConfig::from_toml(
            r#"
            [resilience]
            read_timeout_ms = 50
            write_timeout_ms = 200
            idle_timeout_ms = 0
            max_line_bytes = 4096
            [store]
            dir = ""
            snapshot_interval_ms = 0
            keep = 5
            "#,
        )
        .unwrap();
        assert_eq!(c.resilience.read_timeout_ms, 50);
        assert_eq!(c.resilience.write_timeout_ms, 200);
        assert_eq!(c.resilience.idle_timeout_ms, 0); // idle deadline off
        assert_eq!(c.resilience.max_line_bytes, 4096);
        assert_eq!(c.store.dir, ""); // persistence off
        assert_eq!(c.store.snapshot_interval_ms, 0); // periodic off
        assert_eq!(c.store.keep, 5);
    }

    #[test]
    fn resilience_section_defaults_and_overrides() {
        let c = AsnnConfig::default();
        assert_eq!(c.resilience.deadline_ms, 0); // deadline off by default
        assert_eq!(c.resilience.budget_ms, 0); // budget off by default
        assert_eq!(c.resilience.hedge_delay_ms, 0); // hedging off by default
        assert_eq!(c.resilience.probe_successes, 1);
        assert_eq!(c.resilience.drain_deadline_ms, 500);
        assert!(c.resilience.fallback);
        c.validate().unwrap();

        let c = AsnnConfig::from_toml(
            r#"
            [resilience]
            deadline_ms = 250
            budget_ms = 800
            hedge_delay_ms = 30
            max_inflight = 64
            retry_max = 3
            retry_backoff_us = 1000
            breaker_threshold = 7
            breaker_cooldown_ms = 2000
            probe_successes = 3
            drain_deadline_ms = 750
            fallback = false
            "#,
        )
        .unwrap();
        assert_eq!(c.resilience.deadline_ms, 250);
        assert_eq!(c.resilience.budget_ms, 800);
        assert_eq!(c.resilience.hedge_delay_ms, 30);
        assert_eq!(c.resilience.max_inflight, 64);
        assert_eq!(c.resilience.retry_max, 3);
        assert_eq!(c.resilience.retry_backoff_us, 1000);
        assert_eq!(c.resilience.breaker_threshold, 7);
        assert_eq!(c.resilience.breaker_cooldown_ms, 2000);
        assert_eq!(c.resilience.probe_successes, 3);
        assert_eq!(c.resilience.drain_deadline_ms, 750);
        assert!(!c.resilience.fallback);
    }

    #[test]
    fn obs_and_coarse_skip_defaults_and_overrides() {
        let c = AsnnConfig::default();
        assert!(!c.search.coarse_skip); // off pending the ablation verdict
        assert!(c.obs.enabled);
        assert_eq!(c.obs.export_interval_ms, 10_000);
        c.validate().unwrap();

        let c = AsnnConfig::from_toml(
            r#"
            [search]
            coarse_skip = true
            [obs]
            enabled = false
            export_interval_ms = 0
            "#,
        )
        .unwrap();
        assert!(c.search.coarse_skip);
        assert!(!c.obs.enabled);
        assert_eq!(c.obs.export_interval_ms, 0); // periodic export off
    }

    #[test]
    fn enum_parsers() {
        assert_eq!(Metric::parse("euclidean"), Some(Metric::L2));
        assert_eq!(SearchMode::parse("refined"), Some(SearchMode::Refined));
        assert_eq!(R0Policy::parse("density"), Some(R0Policy::Density));
        assert_eq!(EngineKind::parse("active-pjrt"), Some(EngineKind::ActivePjrt));
        assert_eq!(EngineKind::parse("bogus"), None);
    }
}
