//! `asnn` CLI — launcher for the active-search serving stack.
//!
//! ```text
//! asnn gen-data  --n 10000 --family uniform --out data.bin
//! asnn info      --config asnn.toml
//! asnn query     --n 10000 --k 11 --x 0.5 --y 0.5 --engine active
//! asnn classify  --n 30000 --queries 100 --engine active
//! asnn serve     --config asnn.toml [--artifacts artifacts]
//! asnn viz       fig1 fig2 --out out
//! asnn bench     fig3|accuracy (thin wrappers; full runs via cargo bench)
//! ```

use std::path::Path;
use std::sync::Arc;

use asnn::config::{AsnnConfig, EngineKind, Metric, R0Policy, SearchMode};
use asnn::coordinator::{
    IoLimits, Metrics, ResiliencePolicy, Router, Server, SnapshotSource, Snapshotter, ThreadPool,
};
use asnn::data::synthetic::{generate, generate_queries, Family, SyntheticSpec};
use asnn::data::{io as dio, Dataset};
use asnn::engine::active::{ActiveEngine, ActiveParams};
#[cfg(feature = "pjrt")]
use asnn::engine::active_pjrt::ActivePjrtEngine;
use asnn::engine::brute::BruteEngine;
use asnn::engine::kdtree::KdTreeEngine;
use asnn::engine::lsh::{LshEngine, LshParams};
use asnn::engine::NnEngine;
use asnn::error::{AsnnError, Result};
use asnn::grid::{snapshot as grid_snapshot, MultiGrid};
use asnn::obs::Recorder;
use asnn::store::{self, SnapshotStore};
#[cfg(feature = "pjrt")]
use asnn::runtime::RuntimeService;
use asnn::util::cli::Args;
use asnn::util::timer::Timer;
use asnn::viz;

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("asnn: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("gen-data") => cmd_gen_data(args),
        Some("info") => cmd_info(args),
        Some("query") => cmd_query(args),
        Some("classify") => cmd_classify(args),
        Some("serve") => cmd_serve(args),
        Some("viz") => cmd_viz(args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => Err(AsnnError::Config(format!(
            "unknown subcommand {other:?} (try `asnn help`)"
        ))),
    }
}

fn print_help() {
    println!(
        "asnn — Active Search for Nearest Neighbors (Um & Choi 2019)\n\
         subcommands:\n  \
         gen-data --n N [--family uniform|blobs|rings] [--classes C] [--seed S] --out FILE[.csv]\n  \
         info     [--config FILE]\n  \
         query    [--config FILE] [--data FILE] --x X --y Y [--k K] [--engine E]\n  \
         classify [--config FILE] [--queries Q] [--engine E]\n  \
         serve    [--config FILE] [--artifacts DIR]\n  \
         viz      fig1 fig2 [--out DIR]\n\
         engines: brute kdtree lsh active active-pjrt"
    );
}

/// Load config (defaults if --config absent), with CLI overrides.
fn load_config(args: &Args) -> Result<AsnnConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => AsnnConfig::load(Path::new(path))?,
        None => AsnnConfig::default(),
    };
    cfg.data.n = args.get_usize("n", cfg.data.n)?;
    cfg.data.seed = args.get_u64("seed", cfg.data.seed)?;
    if let Some(f) = args.get("family") {
        cfg.data.family = Family::parse(f)
            .ok_or_else(|| AsnnError::Config(format!("unknown family {f:?}")))?;
    }
    cfg.data.num_classes = args.get_usize("classes", cfg.data.num_classes)?;
    cfg.grid.resolution = args.get_usize("resolution", cfg.grid.resolution)?;
    cfg.search.k = args.get_usize("k", cfg.search.k)?;
    cfg.search.r0 = args.get_u64("r0", cfg.search.r0 as u64)? as u32;
    if let Some(m) = args.get("metric") {
        cfg.search.metric = Metric::parse(m)
            .ok_or_else(|| AsnnError::Config(format!("unknown metric {m:?}")))?;
    }
    if let Some(m) = args.get("mode") {
        cfg.search.mode = SearchMode::parse(m)
            .ok_or_else(|| AsnnError::Config(format!("unknown mode {m:?}")))?;
    }
    if let Some(p) = args.get("r0-policy") {
        cfg.search.r0_policy = R0Policy::parse(p)
            .ok_or_else(|| AsnnError::Config(format!("unknown r0 policy {p:?}")))?;
    }
    if let Some(e) = args.get("engine") {
        cfg.engine = EngineKind::parse(e)
            .ok_or_else(|| AsnnError::Config(format!("unknown engine {e:?}")))?;
    }
    if let Some(dir) = args.get("artifacts") {
        cfg.runtime.artifacts_dir = dir.to_string();
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Dataset from --data file or synthesized per config.
fn load_dataset(args: &Args, cfg: &AsnnConfig) -> Result<Arc<Dataset>> {
    if let Some(path) = args.get("data") {
        let p = Path::new(path);
        let ds = if path.ends_with(".csv") { dio::load_csv(p)? } else { dio::load_bin(p)? };
        Ok(Arc::new(ds))
    } else {
        Ok(Arc::new(generate(&SyntheticSpec {
            family: cfg.data.family,
            n: cfg.data.n,
            dim: cfg.data.dim,
            num_classes: cfg.data.num_classes,
            seed: cfg.data.seed,
            blob_std: 0.06,
        })))
    }
}

fn active_params(cfg: &AsnnConfig) -> ActiveParams {
    ActiveParams {
        r0: cfg.search.r0,
        max_iters: cfg.search.max_iters,
        metric: cfg.search.metric,
        mode: cfg.search.mode,
        r0_policy: cfg.search.r0_policy,
        tolerance: cfg.search.tolerance,
        coarse_skip: cfg.search.coarse_skip,
    }
}

/// Build one engine per config kind.
fn build_engine(cfg: &AsnnConfig, ds: Arc<Dataset>) -> Result<Arc<dyn NnEngine>> {
    Ok(match cfg.engine {
        EngineKind::Brute => Arc::new(BruteEngine::new(ds)),
        EngineKind::KdTree => Arc::new(KdTreeEngine::build(ds)),
        EngineKind::Lsh => Arc::new(LshEngine::build(ds, LshParams::default())),
        EngineKind::Active => {
            Arc::new(ActiveEngine::new(ds, cfg.grid.resolution, active_params(cfg))?)
        }
        #[cfg(feature = "pjrt")]
        EngineKind::ActivePjrt => {
            let service = RuntimeService::spawn(Path::new(&cfg.runtime.artifacts_dir).into())?;
            Arc::new(ActivePjrtEngine::new(ds, cfg.grid.resolution, active_params(cfg), service)?)
        }
        #[cfg(not(feature = "pjrt"))]
        EngineKind::ActivePjrt => {
            return Err(AsnnError::Config(
                "engine \"active-pjrt\" requires building with the `pjrt` feature".into(),
            ))
        }
    })
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let out = args.require("out")?;
    let ds = generate(&SyntheticSpec {
        family: cfg.data.family,
        n: cfg.data.n,
        dim: cfg.data.dim,
        num_classes: cfg.data.num_classes,
        seed: cfg.data.seed,
        blob_std: 0.06,
    });
    let path = Path::new(out);
    if out.ends_with(".csv") {
        dio::save_csv(&ds, path)?;
    } else {
        dio::save_bin(&ds, path)?;
    }
    println!(
        "wrote {} points ({} classes, dim {}) to {}",
        ds.len(),
        ds.num_classes,
        ds.dim,
        out
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let ds = load_dataset(args, &cfg)?;
    let t = Timer::new();
    let grid = MultiGrid::build(&ds, cfg.grid.resolution)?;
    println!("dataset: n={} dim={} classes={}", ds.len(), ds.dim, ds.num_classes);
    println!(
        "grid: {0}x{0} build={1:.3}s mem={2:.1} MiB occupied={3} overlap={4:.4}",
        cfg.grid.resolution,
        t.elapsed_secs(),
        grid.memory_bytes() as f64 / (1024.0 * 1024.0),
        grid.occupied_cells(),
        grid.overlap_fraction()
    );
    println!(
        "search: k={} r0={} metric={} engine={}",
        cfg.search.k,
        cfg.search.r0,
        cfg.search.metric.name(),
        cfg.engine.name()
    );
    Ok(())
}

fn cmd_query(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let x = args.get_f64("x", f64::NAN)?;
    let y = args.get_f64("y", f64::NAN)?;
    if !x.is_finite() || !y.is_finite() {
        return Err(AsnnError::Config("query needs --x and --y".into()));
    }
    let ds = load_dataset(args, &cfg)?;
    let engine = build_engine(&cfg, ds)?;
    let t = Timer::new();
    let hits = engine.knn(&[x, y], cfg.search.k)?;
    let dt = t.elapsed_secs();
    println!("engine={} k={} elapsed={:.6}s", engine.name(), cfg.search.k, dt);
    for h in hits {
        println!("  id={} dist={:.6} label={}", h.id, h.dist, h.label);
    }
    Ok(())
}

fn cmd_classify(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n_queries = args.get_usize("queries", 100)?;
    let ds = load_dataset(args, &cfg)?;
    let engine = build_engine(&cfg, ds.clone())?;
    let truth = BruteEngine::new(ds);
    let queries = generate_queries(n_queries, 2, cfg.data.seed + 1);
    let t = Timer::new();
    let mut agree = 0usize;
    for q in &queries {
        let a = engine.classify(q, cfg.search.k)?;
        let b = truth.classify(q, cfg.search.k)?;
        if a == b {
            agree += 1;
        }
    }
    println!(
        "engine={} queries={} agreement={:.1}% elapsed={:.3}s",
        engine.name(),
        n_queries,
        100.0 * agree as f64 / n_queries as f64,
        t.elapsed_secs()
    );
    Ok(())
}

/// Warm-boot the dataset from the newest valid snapshot generation,
/// falling back to `None` (cold boot) when the store is empty or the
/// payload does not decode.
fn recover_dataset(store: &SnapshotStore, metrics: &Metrics) -> Option<Arc<Dataset>> {
    let snap = match store.load_latest() {
        Ok(Some(snap)) => snap,
        Ok(None) => return None,
        Err(e) => {
            eprintln!("store: dataset recovery failed: {e}");
            return None;
        }
    };
    metrics.record_corrupt_quarantined(snap.quarantined.len() as u64);
    match dio::dataset_from_bytes(&snap.payload) {
        Ok(ds) => {
            println!("warm boot: dataset from snapshot generation {}", snap.seq);
            Some(Arc::new(ds))
        }
        Err(e) => {
            eprintln!("store: dataset snapshot unusable, regenerating: {e}");
            None
        }
    }
}

/// Warm-boot the active engine from a grid snapshot; any mismatch with
/// the dataset or configured resolution falls back to a fresh build.
fn recover_active_engine(
    store: &SnapshotStore,
    ds: &Arc<Dataset>,
    cfg: &AsnnConfig,
    metrics: &Metrics,
) -> Option<ActiveEngine> {
    let snap = match store.load_latest() {
        Ok(Some(snap)) => snap,
        _ => return None,
    };
    metrics.record_corrupt_quarantined(snap.quarantined.len() as u64);
    let restored = grid_snapshot::from_bytes(&snap.payload).and_then(|grid| {
        if grid.resolution() != cfg.grid.resolution {
            return Err(AsnnError::Grid(format!(
                "snapshot resolution {} != configured {}",
                grid.resolution(),
                cfg.grid.resolution
            )));
        }
        ActiveEngine::restore(grid, Arc::clone(ds), active_params(cfg))
    });
    match restored {
        Ok(engine) => {
            println!("warm boot: grid index from snapshot generation {}", snap.seq);
            Some(engine)
        }
        Err(e) => {
            eprintln!("store: grid snapshot unusable, rebuilding: {e}");
            None
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let metrics = Arc::new(Metrics::new());

    // boot-time recovery pass over the state dir: quarantine torn
    // files, then warm-boot dataset and grid from the newest valid
    // snapshot generations (HEALTH reports status=recovering until
    // the listener is up)
    let store_dir =
        (!cfg.store.dir.is_empty()).then(|| Path::new(&cfg.store.dir).to_path_buf());
    let stores = store_dir.as_ref().map(|dir| {
        (
            SnapshotStore::new(dir.clone(), "dataset", cfg.store.keep),
            SnapshotStore::new(dir.clone(), "grid", cfg.store.keep),
        )
    });
    let mut recovered_ds = None;
    if let (Some(dir), Some((ds_store, _))) = (&store_dir, &stores) {
        metrics.set_recovering(true);
        let report = store::recover(dir)?;
        metrics.record_corrupt_quarantined(report.quarantined.len() as u64);
        if report.scanned > 0 {
            println!("store recovery: {}", report.summary());
        }
        // an explicit --data file outranks any snapshot
        if args.get("data").is_none() {
            recovered_ds = recover_dataset(ds_store, &metrics);
        }
    }
    let ds = match recovered_ds {
        Some(ds) => ds,
        None => load_dataset(args, &cfg)?,
    };

    // shared observability recorder: the active engine self-reports
    // coarse/refine/scan spans into it, the router adds per-engine
    // counters plus retry/hedge/batch-wait spans, and STATS2/TRACE
    // read it back out. Restored from the last obs export so stage
    // histograms survive restarts.
    let recorder = cfg.obs.enabled.then(|| Arc::new(Recorder::new()));
    let obs_store = store_dir
        .as_ref()
        .map(|dir| SnapshotStore::new(dir.clone(), "obs", cfg.store.keep));
    if let (Some(rec), Some(os)) = (&recorder, &obs_store) {
        match os.load_latest() {
            Ok(Some(snap)) => {
                metrics.record_corrupt_quarantined(snap.quarantined.len() as u64);
                match rec.restore_bytes(&snap.payload) {
                    Ok(()) => {
                        println!("warm boot: obs counters from snapshot generation {}", snap.seq)
                    }
                    Err(e) => eprintln!("store: obs snapshot unusable, starting fresh: {e}"),
                }
            }
            Ok(None) => {}
            Err(e) => eprintln!("store: obs recovery failed: {e}"),
        }
    }

    let active = {
        let restored = stores
            .as_ref()
            .and_then(|(_, gs)| recover_active_engine(gs, &ds, &cfg, &metrics));
        let mut engine = match restored {
            Some(engine) => engine,
            None => ActiveEngine::new(ds.clone(), cfg.grid.resolution, active_params(&cfg))?,
        };
        if let Some(rec) = &recorder {
            engine.set_recorder(Arc::clone(rec));
        }
        Arc::new(engine)
    };

    let policy = ResiliencePolicy::from_config(&cfg.resilience);
    let mut router = Router::with_policy(cfg.engine.name(), Arc::clone(&metrics), policy);
    if let Some(rec) = &recorder {
        router.set_recorder(Arc::clone(rec));
    }
    // always register the cheap engines; PJRT only when artifacts
    // exist. register_engine keys each on its own EngineInfo name.
    router.register_engine(Arc::new(BruteEngine::new(ds.clone())));
    router.register_engine(Arc::new(KdTreeEngine::build(ds.clone())));
    router.register_engine(Arc::new(LshEngine::build(ds.clone(), LshParams::default())));
    router.register_engine(Arc::clone(&active) as Arc<dyn NnEngine>);
    let artifacts = Path::new(&cfg.runtime.artifacts_dir);
    #[cfg(feature = "pjrt")]
    if artifacts.join("manifest.toml").exists() {
        let service = RuntimeService::spawn(artifacts.into())?;
        router.register_engine(Arc::new(ActivePjrtEngine::new(
            ds.clone(),
            cfg.grid.resolution,
            active_params(&cfg),
            service,
        )?));
        println!("loaded PJRT artifacts from {}", artifacts.display());
    } else {
        println!("no artifacts at {} — PJRT engine disabled", artifacts.display());
    }
    #[cfg(not(feature = "pjrt"))]
    println!(
        "built without the pjrt feature — PJRT engine disabled (artifacts dir: {})",
        artifacts.display()
    );
    // dedicated pool for batch fan-out (NOT the connection pool: batch
    // chunks queued behind connections would self-deadlock), then the
    // batching lane so engine-less KNNs group into shared flights
    router.set_batch_pool(Arc::new(ThreadPool::new(cfg.server.batch_workers)));
    let router = Arc::new(router);
    router.attach_batch_lane(
        cfg.server.batch_max,
        std::time::Duration::from_micros(cfg.server.batch_deadline_us),
        (cfg.resilience.budget_ms > 0)
            .then(|| std::time::Duration::from_millis(cfg.resilience.budget_ms)),
    );
    let server = Server::new(Arc::clone(&router), cfg.server.workers)
        .with_max_inflight(cfg.resilience.max_inflight)
        .with_drain_deadline(std::time::Duration::from_millis(
            cfg.resilience.drain_deadline_ms,
        ))
        .with_io_limits(IoLimits {
            read_timeout: std::time::Duration::from_millis(cfg.resilience.read_timeout_ms),
            write_timeout: std::time::Duration::from_millis(cfg.resilience.write_timeout_ms),
            idle_timeout: std::time::Duration::from_millis(cfg.resilience.idle_timeout_ms),
            max_line_bytes: cfg.resilience.max_line_bytes,
        });
    let handle = server.spawn(&cfg.server.addr)?;
    metrics.set_recovering(false);

    // keep the serving state warm-restartable: publish dataset + grid
    // snapshots now, then repair them every snapshot_interval_ms
    let _snapshotter = match &stores {
        Some((ds_store, grid_store)) => Some(Snapshotter::spawn(
            vec![
                (ds_store.clone(), dio::dataset_to_bytes(&ds)),
                (grid_store.clone(), grid_snapshot::to_bytes(active.grid())),
            ],
            std::time::Duration::from_millis(cfg.store.snapshot_interval_ms),
            Arc::clone(&metrics),
        )?),
        None => None,
    };

    // observability export rides its own snapshotter because its
    // cadence (obs.export_interval_ms) is independent of the state
    // snapshot repair interval; the dynamic source re-reads the
    // recorder every tick so the newest counters are what survive
    let _obs_snapshotter = match (&recorder, &obs_store) {
        (Some(rec), Some(os)) if cfg.obs.export_interval_ms > 0 => {
            let rec = Arc::clone(rec);
            Some(Snapshotter::spawn_sources(
                vec![SnapshotSource::dynamic(os.clone(), move || rec.export_bytes())],
                std::time::Duration::from_millis(cfg.obs.export_interval_ms),
                Arc::clone(&metrics),
            )?)
        }
        _ => None,
    };

    println!(
        "serving on {} (engines ready; deadline={}ms budget={}ms hedge={}ms \
         max_inflight={} store={}; Ctrl-C to stop)",
        handle.addr,
        cfg.resilience.deadline_ms,
        cfg.resilience.budget_ms,
        cfg.resilience.hedge_delay_ms,
        cfg.resilience.max_inflight,
        store_dir
            .as_ref()
            .map(|d| d.display().to_string())
            .unwrap_or_else(|| "disabled".into())
    );
    // block forever (no signal handling crates offline; Ctrl-C kills us)
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_viz(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let out_dir = Path::new(args.get_or("out", "out"));
    let want = |name: &str| args.positionals.is_empty() || args.positionals.iter().any(|p| p == name);
    if want("fig1") {
        // the paper's 15-point illustration
        let ds = generate(&SyntheticSpec::blobs(15, 3, cfg.data.seed));
        let scatter = viz::render_scatter(&ds, 600, 4)?;
        scatter.save_ppm(&out_dir.join("fig1_vectors.ppm"))?;
        let grid = MultiGrid::build(&ds, 600)?;
        let image = viz::render_grid(&grid, 4);
        image.save_ppm(&out_dir.join("fig1_image.ppm"))?;
        println!("wrote fig1_vectors.ppm fig1_image.ppm to {}", out_dir.display());
    }
    if want("fig2") {
        let ds = Arc::new(generate(&SyntheticSpec::blobs(400, 3, cfg.data.seed + 2)));
        let engine = ActiveEngine::new(ds.clone(), 600, active_params(&cfg))?;
        let q = [0.45, 0.55];
        let circle = engine.search(&q, cfg.search.k)?;
        let img = viz::render_trace(engine.grid(), (circle.cx, circle.cy), &circle.trace, 2);
        img.save_ppm(&out_dir.join("fig2_trace.ppm"))?;
        println!(
            "wrote fig2_trace.ppm ({} iterations, final r={}) to {}",
            circle.trace.iterations(),
            circle.r,
            out_dir.display()
        );
    }
    Ok(())
}
