//! Wire protocol: newline-delimited text, one request per line.
//!
//! Requests:
//! ```text
//! KNN <k> <x> <y> [engine]        → OK <id>:<dist>:<label> ...
//! KNNB <k> <n> <x1> <y1> ... <xn> <yn> [engine]
//!                                 → OK B <n> ; <entry> ; ... ; <entry>
//! CLASSIFY <k> <x> <y> [engine]   → OK <label>
//! STATS                           → OK <metrics text, one line — frozen legacy format>
//! STATS2 [json|text] [section]    → OK <structured telemetry document>
//! TRACE <x> <y> <k> [engine]      → OK <one query's span tree, JSON>
//! HEALTH                          → OK status=... engines=... breakers=... queue_depth=N
//! PING                            → OK pong
//! QUIT                            → closes the connection
//! ```
//! `HEALTH` is for load-balancer readiness probes: it reports the
//! registered engines, each circuit breaker's state, and the current
//! queue depth without touching any engine.
//!
//! `KNNB` answers one batch in one line: entry `i` belongs to query
//! `i` and is either a space-joined run of `id:dist:label` triplets
//! (possibly empty) or `!<code> <message>` for a per-query failure —
//! one bad query never poisons its batchmates.
//!
//! `STATS2` is the versioned telemetry verb (`docs/OBSERVABILITY.md`):
//! format defaults to `json`; `section` narrows the document to
//! `stages`, `engines`, or `coordinator`. The legacy one-line `STATS`
//! is a frozen compatibility shim — its byte format never changes.
//!
//! Errors: `ERR <code> <detail>`, where `<code>` is one of the stable
//! [`ErrCode`] names shared by the single and batched paths (the same
//! codes appear after `!` in batch entries). Codes are documented in
//! `docs/RESILIENCE.md`.

use crate::engine::Neighbor;
use crate::error::{AsnnError, Result};

/// Stable machine-readable error code carried by `ERR <code> <detail>`
/// lines and `!<code> <message>` batch entries.
///
/// The wire names are frozen: they are exactly the [`AsnnError::tag`]
/// domains plus the server's `too-long` I/O rejection, and `unknown`
/// for codes a newer server might emit that this client predates.
/// Adding a variant is backward-compatible; renaming one is a breaking
/// protocol change.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ErrCode {
    Config,
    Data,
    Grid,
    Query,
    Runtime,
    Coordinator,
    Protocol,
    Overload,
    Timeout,
    Store,
    Io,
    /// Request line exceeded the server's line-length limit.
    TooLong,
    /// Unrecognized code from a foreign/newer peer (parse-side only).
    Unknown,
}

impl ErrCode {
    /// Every concrete code (excludes the parse-side `Unknown` catchall).
    pub const ALL: [ErrCode; 12] = [
        ErrCode::Config,
        ErrCode::Data,
        ErrCode::Grid,
        ErrCode::Query,
        ErrCode::Runtime,
        ErrCode::Coordinator,
        ErrCode::Protocol,
        ErrCode::Overload,
        ErrCode::Timeout,
        ErrCode::Store,
        ErrCode::Io,
        ErrCode::TooLong,
    ];

    /// The frozen wire name.
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrCode::Config => "config",
            ErrCode::Data => "data",
            ErrCode::Grid => "grid",
            ErrCode::Query => "query",
            ErrCode::Runtime => "runtime",
            ErrCode::Coordinator => "coordinator",
            ErrCode::Protocol => "protocol",
            ErrCode::Overload => "overload",
            ErrCode::Timeout => "timeout",
            ErrCode::Store => "store",
            ErrCode::Io => "io",
            ErrCode::TooLong => "too-long",
            ErrCode::Unknown => "unknown",
        }
    }

    /// Parse a wire name; `None` for anything unrecognized.
    pub fn parse(s: &str) -> Option<ErrCode> {
        ErrCode::ALL.into_iter().find(|c| c.as_str() == s)
    }

    /// Lossy parse for the client side: unrecognized names collapse to
    /// [`ErrCode::Unknown`] so response parsing stays total.
    pub fn parse_lossy(s: &str) -> ErrCode {
        ErrCode::parse(s).unwrap_or(ErrCode::Unknown)
    }
}

impl From<&AsnnError> for ErrCode {
    fn from(e: &AsnnError) -> ErrCode {
        // tag() is the single source of truth for error→code naming;
        // every tag has a matching variant (enforced by test below).
        ErrCode::parse_lossy(e.tag())
    }
}

impl std::fmt::Display for ErrCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Output format selector for `STATS2`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatsFormat {
    Json,
    Text,
}

impl StatsFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            StatsFormat::Json => "json",
            StatsFormat::Text => "text",
        }
    }
}

/// Section selector for `STATS2` (omitted = the full document).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatsSection {
    /// Per-stage latency histograms (coarse/refine/scan/retry/hedge/
    /// batch_wait).
    Stages,
    /// Per-engine request/error/batch counters and latency.
    Engines,
    /// Coordinator counters (the structured form of legacy `STATS`).
    Coordinator,
}

impl StatsSection {
    pub fn as_str(&self) -> &'static str {
        match self {
            StatsSection::Stages => "stages",
            StatsSection::Engines => "engines",
            StatsSection::Coordinator => "coordinator",
        }
    }

    pub fn parse(s: &str) -> Option<StatsSection> {
        match s {
            "stages" => Some(StatsSection::Stages),
            "engines" => Some(StatsSection::Engines),
            "coordinator" => Some(StatsSection::Coordinator),
            _ => None,
        }
    }
}

/// Largest accepted `KNNB` batch. Checked before any allocation so a
/// hostile header cannot reserve unbounded memory.
pub const MAX_BATCH: usize = 4096;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Knn { k: usize, x: f64, y: f64, engine: Option<String> },
    Knnb { k: usize, queries: Vec<[f64; 2]>, engine: Option<String> },
    Classify { k: usize, x: f64, y: f64, engine: Option<String> },
    Stats,
    Stats2 { format: StatsFormat, section: Option<StatsSection> },
    Trace { k: usize, x: f64, y: f64, engine: Option<String> },
    Health,
    Ping,
    Quit,
}

impl Request {
    /// Parse one protocol line.
    pub fn parse(line: &str) -> Result<Request> {
        let mut it = line.split_whitespace();
        let verb = it
            .next()
            .ok_or_else(|| AsnnError::Protocol("empty request".into()))?
            .to_ascii_uppercase();
        let parse_query = |it: &mut dyn Iterator<Item = &str>| -> Result<(usize, f64, f64, Option<String>)> {
            let k: usize = it
                .next()
                .ok_or_else(|| AsnnError::Protocol("missing k".into()))?
                .parse()
                .map_err(|_| AsnnError::Protocol("bad k".into()))?;
            let x: f64 = it
                .next()
                .ok_or_else(|| AsnnError::Protocol("missing x".into()))?
                .parse()
                .map_err(|_| AsnnError::Protocol("bad x".into()))?;
            let y: f64 = it
                .next()
                .ok_or_else(|| AsnnError::Protocol("missing y".into()))?
                .parse()
                .map_err(|_| AsnnError::Protocol("bad y".into()))?;
            let engine = it.next().map(|s| s.to_string());
            Ok((k, x, y, engine))
        };
        match verb.as_str() {
            "KNN" => {
                let (k, x, y, engine) = parse_query(&mut it)?;
                Ok(Request::Knn { k, x, y, engine })
            }
            "KNNB" => {
                let k: usize = it
                    .next()
                    .ok_or_else(|| AsnnError::Protocol("missing k".into()))?
                    .parse()
                    .map_err(|_| AsnnError::Protocol("bad k".into()))?;
                let n: usize = it
                    .next()
                    .ok_or_else(|| AsnnError::Protocol("missing n".into()))?
                    .parse()
                    .map_err(|_| AsnnError::Protocol("bad n".into()))?;
                if n == 0 {
                    return Err(AsnnError::Protocol("empty batch".into()));
                }
                if n > MAX_BATCH {
                    return Err(AsnnError::Protocol(format!(
                        "batch size {n} exceeds max {MAX_BATCH}"
                    )));
                }
                let mut queries = Vec::with_capacity(n);
                for _ in 0..n {
                    let x: f64 = it
                        .next()
                        .ok_or_else(|| AsnnError::Protocol("missing x".into()))?
                        .parse()
                        .map_err(|_| AsnnError::Protocol("bad x".into()))?;
                    let y: f64 = it
                        .next()
                        .ok_or_else(|| AsnnError::Protocol("missing y".into()))?
                        .parse()
                        .map_err(|_| AsnnError::Protocol("bad y".into()))?;
                    queries.push([x, y]);
                }
                let engine = it.next().map(|s| s.to_string());
                Ok(Request::Knnb { k, queries, engine })
            }
            "CLASSIFY" => {
                let (k, x, y, engine) = parse_query(&mut it)?;
                Ok(Request::Classify { k, x, y, engine })
            }
            "STATS" => Ok(Request::Stats),
            "STATS2" => {
                let format = match it.next() {
                    None => StatsFormat::Json,
                    Some(f) => match f.to_ascii_lowercase().as_str() {
                        "json" => StatsFormat::Json,
                        "text" => StatsFormat::Text,
                        other => {
                            return Err(AsnnError::Protocol(format!(
                                "bad STATS2 format {other:?} (want json|text)"
                            )))
                        }
                    },
                };
                let section = match it.next() {
                    None => None,
                    Some(s) => Some(StatsSection::parse(&s.to_ascii_lowercase()).ok_or_else(
                        || {
                            AsnnError::Protocol(format!(
                                "bad STATS2 section {s:?} (want stages|engines|coordinator)"
                            ))
                        },
                    )?),
                };
                if it.next().is_some() {
                    return Err(AsnnError::Protocol("trailing tokens after STATS2".into()));
                }
                Ok(Request::Stats2 { format, section })
            }
            "TRACE" => {
                let coord = |it: &mut dyn Iterator<Item = &str>, what: &str| -> Result<f64> {
                    it.next()
                        .ok_or_else(|| AsnnError::Protocol(format!("missing {what}")))?
                        .parse()
                        .map_err(|_| AsnnError::Protocol(format!("bad {what}")))
                };
                let x = coord(&mut it, "x")?;
                let y = coord(&mut it, "y")?;
                let k: usize = it
                    .next()
                    .ok_or_else(|| AsnnError::Protocol("missing k".into()))?
                    .parse()
                    .map_err(|_| AsnnError::Protocol("bad k".into()))?;
                let engine = it.next().map(|s| s.to_string());
                if it.next().is_some() {
                    return Err(AsnnError::Protocol("trailing tokens after TRACE".into()));
                }
                Ok(Request::Trace { k, x, y, engine })
            }
            "HEALTH" => Ok(Request::Health),
            "PING" => Ok(Request::Ping),
            "QUIT" => Ok(Request::Quit),
            other => Err(AsnnError::Protocol(format!("unknown verb {other:?}"))),
        }
    }

    /// Serialize back to a protocol line (client side).
    pub fn format(&self) -> String {
        match self {
            Request::Knn { k, x, y, engine } => match engine {
                Some(e) => format!("KNN {k} {x} {y} {e}"),
                None => format!("KNN {k} {x} {y}"),
            },
            Request::Knnb { k, queries, engine } => {
                let mut s = format!("KNNB {k} {}", queries.len());
                for q in queries {
                    s.push_str(&format!(" {} {}", q[0], q[1]));
                }
                if let Some(e) = engine {
                    s.push(' ');
                    s.push_str(e);
                }
                s
            }
            Request::Classify { k, x, y, engine } => match engine {
                Some(e) => format!("CLASSIFY {k} {x} {y} {e}"),
                None => format!("CLASSIFY {k} {x} {y}"),
            },
            Request::Stats => "STATS".into(),
            Request::Stats2 { format, section } => match section {
                Some(s) => format!("STATS2 {} {}", format.as_str(), s.as_str()),
                None => format!("STATS2 {}", format.as_str()),
            },
            Request::Trace { k, x, y, engine } => match engine {
                Some(e) => format!("TRACE {x} {y} {k} {e}"),
                None => format!("TRACE {x} {y} {k}"),
            },
            Request::Health => "HEALTH".into(),
            Request::Ping => "PING".into(),
            Request::Quit => "QUIT".into(),
        }
    }
}

/// One query's slot in a batched (`KNNB`) response.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchEntry {
    /// This query's neighbors (possibly empty).
    Hits(Vec<Neighbor>),
    /// This query failed; its batchmates are unaffected.
    Error { code: ErrCode, message: String },
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Neighbors(Vec<Neighbor>),
    Label(u16),
    Batch(Vec<BatchEntry>),
    Text(String),
    Error { code: ErrCode, message: String },
}

impl Response {
    pub fn format(&self) -> String {
        match self {
            Response::Neighbors(hits) => {
                let body: Vec<String> = hits
                    .iter()
                    .map(|n| format!("{}:{:.6}:{}", n.id, n.dist, n.label))
                    .collect();
                format!("OK {}", body.join(" "))
            }
            Response::Label(l) => format!("OK {l}"),
            Response::Batch(entries) => {
                let body: Vec<String> = entries
                    .iter()
                    .map(|e| match e {
                        BatchEntry::Hits(hits) => hits
                            .iter()
                            .map(|n| format!("{}:{:.6}:{}", n.id, n.dist, n.label))
                            .collect::<Vec<String>>()
                            .join(" "),
                        BatchEntry::Error { code, message } => {
                            // the entry separator and newline must never
                            // appear inside a message
                            format!("!{code} {}", message.replace([';', '\n'], " "))
                        }
                    })
                    .collect();
                format!("OK B {} ; {}", entries.len(), body.join(" ; "))
            }
            Response::Text(t) => format!("OK {}", t.replace('\n', " | ")),
            Response::Error { code, message } => {
                format!("ERR {code} {}", message.replace('\n', " "))
            }
        }
    }

    /// Parse a response line (client side).
    pub fn parse(line: &str) -> Result<Response> {
        if let Some(rest) = line.strip_prefix("ERR ") {
            let (code, message) = rest.split_once(' ').unwrap_or((rest, ""));
            return Ok(Response::Error {
                code: ErrCode::parse_lossy(code),
                message: message.into(),
            });
        }
        let Some(rest) = line.strip_prefix("OK") else {
            return Err(AsnnError::Protocol(format!("bad response line {line:?}")));
        };
        let rest = rest.trim_start();
        // batched form next: "B <n> ; <entry> ; ..." (any malformation
        // falls through to the generic forms — parse stays total)
        if let Some(batch) = Self::parse_batch(rest) {
            return Ok(batch);
        }
        // try neighbors form first: id:dist:label triplets
        if !rest.is_empty() && rest.split_whitespace().all(|t| t.matches(':').count() == 2) {
            let mut hits = Vec::new();
            let mut ok = true;
            for tok in rest.split_whitespace() {
                let parts: Vec<&str> = tok.split(':').collect();
                match (
                    parts[0].parse::<u32>(),
                    parts[1].parse::<f64>(),
                    parts[2].parse::<u16>(),
                ) {
                    (Ok(id), Ok(dist), Ok(label)) => hits.push(Neighbor { id, dist, label }),
                    _ => {
                        ok = false;
                        break;
                    }
                }
            }
            if ok && !hits.is_empty() {
                return Ok(Response::Neighbors(hits));
            }
        }
        if let Ok(label) = rest.parse::<u16>() {
            return Ok(Response::Label(label));
        }
        Ok(Response::Text(rest.to_string()))
    }

    pub fn from_error(e: &AsnnError) -> Response {
        Response::Error { code: ErrCode::from(e), message: e.to_string() }
    }

    /// Parse the batched `B <n> ; <entry> ; ...` body after `OK `.
    /// `None` means "not a well-formed batch" and the caller falls
    /// back to the generic response forms.
    fn parse_batch(rest: &str) -> Option<Response> {
        let rest = rest.strip_prefix("B ")?;
        let (n_str, body) = rest.split_once(" ; ")?;
        let n: usize = n_str.trim().parse().ok()?;
        if n == 0 || n > MAX_BATCH {
            return None;
        }
        let chunks: Vec<&str> = body.split(" ; ").collect();
        if chunks.len() != n {
            return None;
        }
        let mut entries = Vec::with_capacity(n);
        for chunk in chunks {
            let chunk = chunk.trim();
            if let Some(err) = chunk.strip_prefix('!') {
                let (code, message) = err.split_once(' ').unwrap_or((err, ""));
                entries.push(BatchEntry::Error {
                    code: ErrCode::parse_lossy(code),
                    message: message.into(),
                });
                continue;
            }
            let mut hits = Vec::new();
            for tok in chunk.split_whitespace() {
                let parts: Vec<&str> = tok.split(':').collect();
                if parts.len() != 3 {
                    return None;
                }
                match (
                    parts[0].parse::<u32>(),
                    parts[1].parse::<f64>(),
                    parts[2].parse::<u16>(),
                ) {
                    (Ok(id), Ok(dist), Ok(label)) => hits.push(Neighbor { id, dist, label }),
                    _ => return None,
                }
            }
            entries.push(BatchEntry::Hits(hits));
        }
        Some(Response::Batch(entries))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_roundtrip() {
        let r = Request::parse("KNN 11 0.5 0.25 active").unwrap();
        assert_eq!(
            r,
            Request::Knn { k: 11, x: 0.5, y: 0.25, engine: Some("active".into()) }
        );
        assert_eq!(Request::parse(&r.format()).unwrap(), r);
    }

    #[test]
    fn knnb_roundtrip() {
        let r = Request::parse("KNNB 5 3 0.1 0.2 0.3 0.4 0.5 0.6 brute").unwrap();
        assert_eq!(
            r,
            Request::Knnb {
                k: 5,
                queries: vec![[0.1, 0.2], [0.3, 0.4], [0.5, 0.6]],
                engine: Some("brute".into()),
            }
        );
        assert_eq!(Request::parse(&r.format()).unwrap(), r);
        // engine optional
        let r2 = Request::parse("knnb 3 1 0.5 0.5").unwrap();
        assert_eq!(r2, Request::Knnb { k: 3, queries: vec![[0.5, 0.5]], engine: None });
        assert_eq!(Request::parse(&r2.format()).unwrap(), r2);
    }

    #[test]
    fn knnb_rejects_hostile_headers() {
        assert!(Request::parse("KNNB").is_err());
        assert!(Request::parse("KNNB 5").is_err());
        assert!(Request::parse("KNNB 5 0 0.5 0.5").is_err()); // empty batch
        assert!(Request::parse("KNNB 5 2 0.1 0.2").is_err()); // short coords
        assert!(Request::parse("KNNB 5 2 0.1 nope 0.3 0.4").is_err());
        // giant n must be rejected before any allocation happens
        assert!(Request::parse("KNNB 5 18446744073709551615 0.1 0.2").is_err());
        assert!(Request::parse(&format!("KNNB 5 {} 0.1 0.2", MAX_BATCH + 1)).is_err());
    }

    #[test]
    fn batch_response_roundtrip_with_empty_and_error_entries() {
        let resp = Response::Batch(vec![
            BatchEntry::Hits(vec![
                Neighbor { id: 3, dist: 0.125, label: 1 },
                Neighbor { id: 9, dist: 0.5, label: 0 },
            ]),
            BatchEntry::Hits(vec![]), // a query with zero hits
            BatchEntry::Error { code: ErrCode::Query, message: "k = 0 out of range".into() },
        ]);
        let line = resp.format();
        assert!(!line.contains('\n'));
        match Response::parse(&line).unwrap() {
            Response::Batch(entries) => {
                assert_eq!(entries.len(), 3);
                match &entries[0] {
                    BatchEntry::Hits(h) => {
                        assert_eq!(h.len(), 2);
                        assert_eq!(h[0].id, 3);
                        assert!((h[0].dist - 0.125).abs() < 1e-9);
                    }
                    other => panic!("{other:?}"),
                }
                assert_eq!(entries[1], BatchEntry::Hits(vec![]));
                match &entries[2] {
                    BatchEntry::Error { code, message } => {
                        assert_eq!(*code, ErrCode::Query);
                        assert!(message.contains("k = 0"));
                    }
                    other => panic!("{other:?}"),
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batch_error_messages_cannot_forge_the_entry_separator() {
        let resp = Response::Batch(vec![
            BatchEntry::Error { code: ErrCode::Query, message: "evil ; 1:0.5:0 ; x\n".into() },
            BatchEntry::Hits(vec![Neighbor { id: 1, dist: 1.0, label: 0 }]),
        ]);
        match Response::parse(&resp.format()).unwrap() {
            Response::Batch(entries) => assert_eq!(entries.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_batch_responses_fall_back_to_text() {
        for line in [
            "OK B garbage ; x",
            "OK B 3 ; only-one-entry",
            "OK B 1 ; not:triplets:here:4",
            "OK B 0 ; ",
        ] {
            // Text / Label / anything non-panicking is fine — just not a batch
            if let Response::Batch(_) = Response::parse(line).unwrap() {
                panic!("{line:?} parsed as batch");
            }
        }
    }

    #[test]
    fn classify_without_engine() {
        let r = Request::parse("classify 5 0.1 0.9").unwrap();
        assert_eq!(r, Request::Classify { k: 5, x: 0.1, y: 0.9, engine: None });
    }

    #[test]
    fn control_verbs() {
        assert_eq!(Request::parse("PING").unwrap(), Request::Ping);
        assert_eq!(Request::parse("STATS").unwrap(), Request::Stats);
        assert_eq!(Request::parse("HEALTH").unwrap(), Request::Health);
        assert_eq!(Request::parse("health").unwrap(), Request::Health);
        assert_eq!(Request::parse("QUIT").unwrap(), Request::Quit);
        assert_eq!(Request::parse(&Request::Health.format()).unwrap(), Request::Health);
    }

    #[test]
    fn malformed_requests_error() {
        assert!(Request::parse("").is_err());
        assert!(Request::parse("KNN").is_err());
        assert!(Request::parse("KNN x 0.5 0.5").is_err());
        assert!(Request::parse("FROB 1 2 3").is_err());
    }

    #[test]
    fn neighbors_response_roundtrip() {
        let hits = vec![
            Neighbor { id: 3, dist: 0.125, label: 1 },
            Neighbor { id: 9, dist: 0.5, label: 0 },
        ];
        let line = Response::Neighbors(hits.clone()).format();
        match Response::parse(&line).unwrap() {
            Response::Neighbors(parsed) => {
                assert_eq!(parsed.len(), 2);
                assert_eq!(parsed[0].id, 3);
                assert!((parsed[0].dist - 0.125).abs() < 1e-9);
                assert_eq!(parsed[1].label, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn label_response_roundtrip() {
        let line = Response::Label(2).format();
        assert_eq!(Response::parse(&line).unwrap(), Response::Label(2));
    }

    #[test]
    fn error_response_roundtrip() {
        let e = AsnnError::Query("k too large".into());
        let line = Response::from_error(&e).format();
        assert!(line.starts_with("ERR query "));
        match Response::parse(&line).unwrap() {
            Response::Error { code, message } => {
                assert_eq!(code, ErrCode::Query);
                assert!(message.contains("k too large"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn err_code_covers_every_error_tag() {
        // every AsnnError maps onto a real variant, never Unknown
        let samples = [
            AsnnError::Config("c".into()),
            AsnnError::Data("d".into()),
            AsnnError::Grid("g".into()),
            AsnnError::Query("q".into()),
            AsnnError::Runtime("r".into()),
            AsnnError::Coordinator("co".into()),
            AsnnError::Protocol("p".into()),
            AsnnError::Overloaded("o".into()),
            AsnnError::Timeout("t".into()),
            AsnnError::Store("s".into()),
            AsnnError::Io(std::io::Error::other("disk on fire")),
        ];
        for e in &samples {
            let code = ErrCode::from(e);
            assert_ne!(code, ErrCode::Unknown, "tag {:?} has no ErrCode", e.tag());
            assert_eq!(code.as_str(), e.tag());
        }
    }

    #[test]
    fn err_code_wire_names_roundtrip() {
        for code in ErrCode::ALL {
            assert_eq!(ErrCode::parse(code.as_str()), Some(code));
            assert_eq!(ErrCode::parse_lossy(code.as_str()), code);
            assert_eq!(format!("{code}"), code.as_str());
        }
        assert_eq!(ErrCode::parse("too-long"), Some(ErrCode::TooLong));
        assert_eq!(ErrCode::parse("no-such-code"), None);
        assert_eq!(ErrCode::parse_lossy("no-such-code"), ErrCode::Unknown);
    }

    #[test]
    fn foreign_err_codes_parse_as_unknown_not_error() {
        // a newer server may emit codes this client doesn't know —
        // parsing must stay total
        match Response::parse("ERR shiny-new-code something broke").unwrap() {
            Response::Error { code, message } => {
                assert_eq!(code, ErrCode::Unknown);
                assert_eq!(message, "something broke");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats2_parse_defaults_and_roundtrip() {
        let r = Request::parse("STATS2").unwrap();
        assert_eq!(r, Request::Stats2 { format: StatsFormat::Json, section: None });
        assert_eq!(Request::parse(&r.format()).unwrap(), r);

        let r = Request::parse("stats2 text engines").unwrap();
        assert_eq!(
            r,
            Request::Stats2 {
                format: StatsFormat::Text,
                section: Some(StatsSection::Engines),
            }
        );
        assert_eq!(Request::parse(&r.format()).unwrap(), r);

        for section in ["stages", "engines", "coordinator"] {
            let r = Request::parse(&format!("STATS2 json {section}")).unwrap();
            assert_eq!(Request::parse(&r.format()).unwrap(), r);
        }
    }

    #[test]
    fn stats2_rejects_unknown_format_and_section() {
        assert!(Request::parse("STATS2 xml").is_err());
        assert!(Request::parse("STATS2 json nope").is_err());
        assert!(Request::parse("STATS2 json stages extra").is_err());
    }

    #[test]
    fn trace_parse_and_roundtrip() {
        let r = Request::parse("TRACE 0.25 0.75 11").unwrap();
        assert_eq!(r, Request::Trace { k: 11, x: 0.25, y: 0.75, engine: None });
        assert_eq!(Request::parse(&r.format()).unwrap(), r);

        let r = Request::parse("trace 0.5 0.5 3 active").unwrap();
        assert_eq!(r, Request::Trace { k: 3, x: 0.5, y: 0.5, engine: Some("active".into()) });
        assert_eq!(Request::parse(&r.format()).unwrap(), r);
    }

    #[test]
    fn trace_rejects_malformed() {
        assert!(Request::parse("TRACE").is_err());
        assert!(Request::parse("TRACE 0.5").is_err());
        assert!(Request::parse("TRACE 0.5 0.5").is_err());
        assert!(Request::parse("TRACE 0.5 0.5 nope").is_err());
        assert!(Request::parse("TRACE x 0.5 3").is_err());
        assert!(Request::parse("TRACE 0.5 0.5 3 active extra").is_err());
    }
}
