//! Wire protocol: newline-delimited text, one request per line.
//!
//! Requests:
//! ```text
//! KNN <k> <x> <y> [engine]        → OK <id>:<dist>:<label> ...
//! CLASSIFY <k> <x> <y> [engine]   → OK <label>
//! STATS                           → OK <metrics text, one line>
//! HEALTH                          → OK status=... engines=... breakers=... queue_depth=N
//! PING                            → OK pong
//! QUIT                            → closes the connection
//! ```
//! `HEALTH` is for load-balancer readiness probes: it reports the
//! registered engines, each circuit breaker's state, and the current
//! queue depth without touching any engine.
//! Errors: `ERR <domain> <message>`.

use crate::engine::Neighbor;
use crate::error::{AsnnError, Result};

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Knn { k: usize, x: f64, y: f64, engine: Option<String> },
    Classify { k: usize, x: f64, y: f64, engine: Option<String> },
    Stats,
    Health,
    Ping,
    Quit,
}

impl Request {
    /// Parse one protocol line.
    pub fn parse(line: &str) -> Result<Request> {
        let mut it = line.split_whitespace();
        let verb = it
            .next()
            .ok_or_else(|| AsnnError::Protocol("empty request".into()))?
            .to_ascii_uppercase();
        let parse_query = |it: &mut dyn Iterator<Item = &str>| -> Result<(usize, f64, f64, Option<String>)> {
            let k: usize = it
                .next()
                .ok_or_else(|| AsnnError::Protocol("missing k".into()))?
                .parse()
                .map_err(|_| AsnnError::Protocol("bad k".into()))?;
            let x: f64 = it
                .next()
                .ok_or_else(|| AsnnError::Protocol("missing x".into()))?
                .parse()
                .map_err(|_| AsnnError::Protocol("bad x".into()))?;
            let y: f64 = it
                .next()
                .ok_or_else(|| AsnnError::Protocol("missing y".into()))?
                .parse()
                .map_err(|_| AsnnError::Protocol("bad y".into()))?;
            let engine = it.next().map(|s| s.to_string());
            Ok((k, x, y, engine))
        };
        match verb.as_str() {
            "KNN" => {
                let (k, x, y, engine) = parse_query(&mut it)?;
                Ok(Request::Knn { k, x, y, engine })
            }
            "CLASSIFY" => {
                let (k, x, y, engine) = parse_query(&mut it)?;
                Ok(Request::Classify { k, x, y, engine })
            }
            "STATS" => Ok(Request::Stats),
            "HEALTH" => Ok(Request::Health),
            "PING" => Ok(Request::Ping),
            "QUIT" => Ok(Request::Quit),
            other => Err(AsnnError::Protocol(format!("unknown verb {other:?}"))),
        }
    }

    /// Serialize back to a protocol line (client side).
    pub fn format(&self) -> String {
        match self {
            Request::Knn { k, x, y, engine } => match engine {
                Some(e) => format!("KNN {k} {x} {y} {e}"),
                None => format!("KNN {k} {x} {y}"),
            },
            Request::Classify { k, x, y, engine } => match engine {
                Some(e) => format!("CLASSIFY {k} {x} {y} {e}"),
                None => format!("CLASSIFY {k} {x} {y}"),
            },
            Request::Stats => "STATS".into(),
            Request::Health => "HEALTH".into(),
            Request::Ping => "PING".into(),
            Request::Quit => "QUIT".into(),
        }
    }
}

/// A server response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Neighbors(Vec<Neighbor>),
    Label(u16),
    Text(String),
    Error { domain: String, message: String },
}

impl Response {
    pub fn format(&self) -> String {
        match self {
            Response::Neighbors(hits) => {
                let body: Vec<String> = hits
                    .iter()
                    .map(|n| format!("{}:{:.6}:{}", n.id, n.dist, n.label))
                    .collect();
                format!("OK {}", body.join(" "))
            }
            Response::Label(l) => format!("OK {l}"),
            Response::Text(t) => format!("OK {}", t.replace('\n', " | ")),
            Response::Error { domain, message } => {
                format!("ERR {domain} {}", message.replace('\n', " "))
            }
        }
    }

    /// Parse a response line (client side).
    pub fn parse(line: &str) -> Result<Response> {
        if let Some(rest) = line.strip_prefix("ERR ") {
            let (domain, message) = rest.split_once(' ').unwrap_or((rest, ""));
            return Ok(Response::Error { domain: domain.into(), message: message.into() });
        }
        let Some(rest) = line.strip_prefix("OK") else {
            return Err(AsnnError::Protocol(format!("bad response line {line:?}")));
        };
        let rest = rest.trim_start();
        // try neighbors form first: id:dist:label triplets
        if !rest.is_empty() && rest.split_whitespace().all(|t| t.matches(':').count() == 2) {
            let mut hits = Vec::new();
            let mut ok = true;
            for tok in rest.split_whitespace() {
                let parts: Vec<&str> = tok.split(':').collect();
                match (
                    parts[0].parse::<u32>(),
                    parts[1].parse::<f64>(),
                    parts[2].parse::<u16>(),
                ) {
                    (Ok(id), Ok(dist), Ok(label)) => hits.push(Neighbor { id, dist, label }),
                    _ => {
                        ok = false;
                        break;
                    }
                }
            }
            if ok && !hits.is_empty() {
                return Ok(Response::Neighbors(hits));
            }
        }
        if let Ok(label) = rest.parse::<u16>() {
            return Ok(Response::Label(label));
        }
        Ok(Response::Text(rest.to_string()))
    }

    pub fn from_error(e: &AsnnError) -> Response {
        Response::Error { domain: e.tag().into(), message: e.to_string() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_roundtrip() {
        let r = Request::parse("KNN 11 0.5 0.25 active").unwrap();
        assert_eq!(
            r,
            Request::Knn { k: 11, x: 0.5, y: 0.25, engine: Some("active".into()) }
        );
        assert_eq!(Request::parse(&r.format()).unwrap(), r);
    }

    #[test]
    fn classify_without_engine() {
        let r = Request::parse("classify 5 0.1 0.9").unwrap();
        assert_eq!(r, Request::Classify { k: 5, x: 0.1, y: 0.9, engine: None });
    }

    #[test]
    fn control_verbs() {
        assert_eq!(Request::parse("PING").unwrap(), Request::Ping);
        assert_eq!(Request::parse("STATS").unwrap(), Request::Stats);
        assert_eq!(Request::parse("HEALTH").unwrap(), Request::Health);
        assert_eq!(Request::parse("health").unwrap(), Request::Health);
        assert_eq!(Request::parse("QUIT").unwrap(), Request::Quit);
        assert_eq!(Request::parse(&Request::Health.format()).unwrap(), Request::Health);
    }

    #[test]
    fn malformed_requests_error() {
        assert!(Request::parse("").is_err());
        assert!(Request::parse("KNN").is_err());
        assert!(Request::parse("KNN x 0.5 0.5").is_err());
        assert!(Request::parse("FROB 1 2 3").is_err());
    }

    #[test]
    fn neighbors_response_roundtrip() {
        let hits = vec![
            Neighbor { id: 3, dist: 0.125, label: 1 },
            Neighbor { id: 9, dist: 0.5, label: 0 },
        ];
        let line = Response::Neighbors(hits.clone()).format();
        match Response::parse(&line).unwrap() {
            Response::Neighbors(parsed) => {
                assert_eq!(parsed.len(), 2);
                assert_eq!(parsed[0].id, 3);
                assert!((parsed[0].dist - 0.125).abs() < 1e-9);
                assert_eq!(parsed[1].label, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn label_response_roundtrip() {
        let line = Response::Label(2).format();
        assert_eq!(Response::parse(&line).unwrap(), Response::Label(2));
    }

    #[test]
    fn error_response_roundtrip() {
        let e = AsnnError::Query("k too large".into());
        let line = Response::from_error(&e).format();
        match Response::parse(&line).unwrap() {
            Response::Error { domain, message } => {
                assert_eq!(domain, "query");
                assert!(message.contains("k too large"));
            }
            other => panic!("{other:?}"),
        }
    }
}
