//! Background snapshotter: keeps the serving state warm-restartable.
//!
//! The serving dataset and its rasterized grid index are immutable
//! once the server is up, so the snapshotter's job is durability, not
//! freshness: it publishes each payload into its [`SnapshotStore`]
//! immediately at spawn (a fresh server becomes warm-restartable as
//! soon as it is serving), then wakes up every `interval` and
//! *repairs* — if a store no longer holds a valid generation (state
//! dir wiped, files torn by an external fault), it re-publishes.
//! Corrupt generations found while checking are quarantined by the
//! store and counted via `corrupt_quarantined`.
//!
//! Successful saves tick the `snapshots` counter; failed saves tick
//! `snapshot_failures` and are retried on the next interval — a full
//! disk degrades durability, never serving.
//!
//! Two source flavors exist: *fixed* sources carry immutable bytes
//! (dataset, grid index) and are only re-published when the store has
//! lost its valid generation; *dynamic* sources re-evaluate a closure
//! each interval and publish the fresh bytes every tick, so mutating
//! state — the observability recorder's counters and histograms —
//! survives a crash with at most one interval of loss.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::metrics::Metrics;
use crate::error::{AsnnError, Result};
use crate::store::SnapshotStore;

/// Sleep slice so shutdown is observed promptly even with long
/// snapshot intervals.
const SLICE: Duration = Duration::from_millis(50);

/// A store paired with the payload it should durably hold.
pub struct SnapshotSource {
    store: SnapshotStore,
    payload: Payload,
}

enum Payload {
    /// Immutable bytes: published once at spawn, re-published only if
    /// the store loses its valid generation.
    Fixed(Vec<u8>),
    /// Re-evaluated each interval: fresh bytes are published every
    /// tick so mutating state survives a crash.
    Dynamic(Box<dyn Fn() -> Vec<u8> + Send>),
}

impl SnapshotSource {
    /// A source whose payload never changes (dataset, grid index).
    pub fn fixed(store: SnapshotStore, payload: Vec<u8>) -> Self {
        Self { store, payload: Payload::Fixed(payload) }
    }

    /// A source whose payload is recomputed at every interval (the
    /// observability recorder's export).
    pub fn dynamic<F>(store: SnapshotStore, f: F) -> Self
    where
        F: Fn() -> Vec<u8> + Send + 'static,
    {
        Self { store, payload: Payload::Dynamic(Box::new(f)) }
    }
}

/// Handle for the background snapshot thread; stops and joins on drop.
pub struct Snapshotter {
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Snapshotter {
    /// Spawn the snapshot thread over fixed-payload sources. `sources`
    /// pairs each store with the payload bytes it should durably hold.
    /// An `interval` of zero means snapshot once at spawn and never
    /// again (no repair loop).
    pub fn spawn(
        sources: Vec<(SnapshotStore, Vec<u8>)>,
        interval: Duration,
        metrics: Arc<Metrics>,
    ) -> Result<Self> {
        let sources = sources
            .into_iter()
            .map(|(store, payload)| SnapshotSource::fixed(store, payload))
            .collect();
        Self::spawn_sources(sources, interval, metrics)
    }

    /// Spawn the snapshot thread over a mix of fixed and dynamic
    /// sources. Every source is published at spawn; each interval,
    /// fixed sources are repaired if their generation was lost while
    /// dynamic sources re-evaluate their closure and publish fresh.
    pub fn spawn_sources(
        sources: Vec<SnapshotSource>,
        interval: Duration,
        metrics: Arc<Metrics>,
    ) -> Result<Self> {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("asnn-snapshot".into())
            .spawn(move || {
                for src in &sources {
                    match &src.payload {
                        Payload::Fixed(bytes) => publish(&src.store, bytes, &metrics),
                        Payload::Dynamic(f) => publish(&src.store, &f(), &metrics),
                    }
                }
                if interval.is_zero() {
                    return;
                }
                let mut elapsed = Duration::ZERO;
                while !stop2.load(Ordering::SeqCst) {
                    std::thread::sleep(SLICE);
                    elapsed += SLICE;
                    if elapsed >= interval {
                        elapsed = Duration::ZERO;
                        for src in &sources {
                            match &src.payload {
                                Payload::Fixed(bytes) => repair(&src.store, bytes, &metrics),
                                Payload::Dynamic(f) => publish(&src.store, &f(), &metrics),
                            }
                        }
                    }
                }
            })
            .map_err(|e| AsnnError::Coordinator(format!("spawn snapshotter: {e}")))?;
        Ok(Self { stop, join: Some(join) })
    }

    /// Stop the thread and wait for it to exit.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        let Some(join) = self.join.take() else { return };
        self.stop.store(true, Ordering::SeqCst);
        let _ = join.join();
    }
}

impl Drop for Snapshotter {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Unconditionally publish a new generation.
fn publish(store: &SnapshotStore, payload: &[u8], metrics: &Metrics) {
    match store.save(payload) {
        Ok(_) => metrics.record_snapshot(),
        Err(e) => {
            metrics.record_snapshot_failure();
            eprintln!(
                "snapshotter: save failed prefix={} dir={} err={e}",
                store.prefix(),
                store.dir().display()
            );
        }
    }
}

/// Re-publish only if the store no longer holds a valid generation.
/// The validity check walks generations newest-first and quarantines
/// corrupt ones as a side effect, which is exactly the repair we want.
fn repair(store: &SnapshotStore, payload: &[u8], metrics: &Metrics) {
    match store.load_latest() {
        Ok(Some(snap)) => {
            metrics.record_corrupt_quarantined(snap.quarantined.len() as u64);
            // a valid generation survives; nothing to do
        }
        Ok(None) => publish(store, payload, metrics),
        Err(e) => {
            // the check itself failed (I/O error); try to re-publish
            eprintln!(
                "snapshotter: check failed prefix={} err={e}",
                store.prefix()
            );
            publish(store, payload, metrics);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn store(name: &str) -> SnapshotStore {
        let mut p = std::env::temp_dir();
        p.push(format!("asnn-snapshotter-test-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        SnapshotStore::new(p, "s", 3)
    }

    #[test]
    fn snapshots_immediately_at_spawn() {
        let s = store("immediate");
        let metrics = Arc::new(Metrics::new());
        let snapper = Snapshotter::spawn(
            vec![(s.clone(), b"payload".to_vec())],
            Duration::ZERO, // no repair loop: deterministic count
            Arc::clone(&metrics),
        )
        .unwrap();
        // the first snapshot happens before the interval gate, so wait
        // for it rather than for a full period
        let mut ok = false;
        for _ in 0..100 {
            if metrics.snapshot().snapshots >= 1 {
                ok = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(ok, "no snapshot after spawn");
        snapper.shutdown();
        let loaded = s.load_latest().unwrap().unwrap();
        assert_eq!(loaded.payload, b"payload");
        fs::remove_dir_all(s.dir()).ok();
    }

    #[test]
    fn repair_republishes_after_state_dir_wipe() {
        let s = store("repair");
        let metrics = Arc::new(Metrics::new());
        let snapper = Snapshotter::spawn(
            vec![(s.clone(), b"durable".to_vec())],
            Duration::from_millis(100),
            Arc::clone(&metrics),
        )
        .unwrap();
        // wait for the initial snapshot, then wipe the state dir
        for _ in 0..100 {
            if metrics.snapshot().snapshots >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        fs::remove_dir_all(s.dir()).unwrap();
        // the repair loop must notice and re-publish
        let mut ok = false;
        for _ in 0..100 {
            if s.load_latest().ok().flatten().is_some() {
                ok = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(ok, "snapshot not re-published after wipe");
        assert!(metrics.snapshot().snapshots >= 2);
        snapper.shutdown();
        fs::remove_dir_all(s.dir()).ok();
    }

    #[test]
    fn dynamic_source_publishes_fresh_payload_each_interval() {
        use std::sync::atomic::AtomicU64;
        let s = store("dynamic");
        let metrics = Arc::new(Metrics::new());
        let gen = Arc::new(AtomicU64::new(0));
        let gen2 = Arc::clone(&gen);
        let snapper = Snapshotter::spawn_sources(
            vec![SnapshotSource::dynamic(s.clone(), move || {
                let n = gen2.fetch_add(1, Ordering::SeqCst);
                format!("export-{n}").into_bytes()
            })],
            Duration::from_millis(60),
            Arc::clone(&metrics),
        )
        .unwrap();
        // the closure is re-evaluated and re-published every interval,
        // so the latest generation must eventually move past the first
        let mut ok = false;
        for _ in 0..100 {
            if let Ok(Some(snap)) = s.load_latest() {
                if snap.payload != b"export-0" {
                    ok = true;
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(ok, "dynamic payload never refreshed");
        assert!(metrics.snapshot().snapshots >= 2);
        snapper.shutdown();
        fs::remove_dir_all(s.dir()).ok();
    }

    #[test]
    fn valid_generation_is_left_alone() {
        let s = store("leave");
        let metrics = Arc::new(Metrics::new());
        let snapper = Snapshotter::spawn(
            vec![(s.clone(), b"stable".to_vec())],
            Duration::from_millis(60),
            Arc::clone(&metrics),
        )
        .unwrap();
        // several repair periods pass; only the initial publish counts
        std::thread::sleep(Duration::from_millis(400));
        snapper.shutdown();
        assert_eq!(metrics.snapshot().snapshots, 1);
        assert_eq!(s.generations().unwrap().len(), 1);
        fs::remove_dir_all(s.dir()).ok();
    }
}
