//! Background snapshotter: keeps the serving state warm-restartable.
//!
//! The serving dataset and its rasterized grid index are immutable
//! once the server is up, so the snapshotter's job is durability, not
//! freshness: it publishes each payload into its [`SnapshotStore`]
//! immediately at spawn (a fresh server becomes warm-restartable as
//! soon as it is serving), then wakes up every `interval` and
//! *repairs* — if a store no longer holds a valid generation (state
//! dir wiped, files torn by an external fault), it re-publishes.
//! Corrupt generations found while checking are quarantined by the
//! store and counted via `corrupt_quarantined`.
//!
//! Successful saves tick the `snapshots` counter; failed saves tick
//! `snapshot_failures` and are retried on the next interval — a full
//! disk degrades durability, never serving.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::metrics::Metrics;
use crate::error::{AsnnError, Result};
use crate::store::SnapshotStore;

/// Sleep slice so shutdown is observed promptly even with long
/// snapshot intervals.
const SLICE: Duration = Duration::from_millis(50);

/// Handle for the background snapshot thread; stops and joins on drop.
pub struct Snapshotter {
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Snapshotter {
    /// Spawn the snapshot thread. `sources` pairs each store with the
    /// payload bytes it should durably hold. An `interval` of zero
    /// means snapshot once at spawn and never again (no repair loop).
    pub fn spawn(
        sources: Vec<(SnapshotStore, Vec<u8>)>,
        interval: Duration,
        metrics: Arc<Metrics>,
    ) -> Result<Self> {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("asnn-snapshot".into())
            .spawn(move || {
                for (store, payload) in &sources {
                    publish(store, payload, &metrics);
                }
                if interval.is_zero() {
                    return;
                }
                let mut elapsed = Duration::ZERO;
                while !stop2.load(Ordering::SeqCst) {
                    std::thread::sleep(SLICE);
                    elapsed += SLICE;
                    if elapsed >= interval {
                        elapsed = Duration::ZERO;
                        for (store, payload) in &sources {
                            repair(store, payload, &metrics);
                        }
                    }
                }
            })
            .map_err(|e| AsnnError::Coordinator(format!("spawn snapshotter: {e}")))?;
        Ok(Self { stop, join: Some(join) })
    }

    /// Stop the thread and wait for it to exit.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        let Some(join) = self.join.take() else { return };
        self.stop.store(true, Ordering::SeqCst);
        let _ = join.join();
    }
}

impl Drop for Snapshotter {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Unconditionally publish a new generation.
fn publish(store: &SnapshotStore, payload: &[u8], metrics: &Metrics) {
    match store.save(payload) {
        Ok(_) => metrics.record_snapshot(),
        Err(e) => {
            metrics.record_snapshot_failure();
            eprintln!(
                "snapshotter: save failed prefix={} dir={} err={e}",
                store.prefix(),
                store.dir().display()
            );
        }
    }
}

/// Re-publish only if the store no longer holds a valid generation.
/// The validity check walks generations newest-first and quarantines
/// corrupt ones as a side effect, which is exactly the repair we want.
fn repair(store: &SnapshotStore, payload: &[u8], metrics: &Metrics) {
    match store.load_latest() {
        Ok(Some(snap)) => {
            metrics.record_corrupt_quarantined(snap.quarantined.len() as u64);
            // a valid generation survives; nothing to do
        }
        Ok(None) => publish(store, payload, metrics),
        Err(e) => {
            // the check itself failed (I/O error); try to re-publish
            eprintln!(
                "snapshotter: check failed prefix={} err={e}",
                store.prefix()
            );
            publish(store, payload, metrics);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn store(name: &str) -> SnapshotStore {
        let mut p = std::env::temp_dir();
        p.push(format!("asnn-snapshotter-test-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        SnapshotStore::new(p, "s", 3)
    }

    #[test]
    fn snapshots_immediately_at_spawn() {
        let s = store("immediate");
        let metrics = Arc::new(Metrics::new());
        let snapper = Snapshotter::spawn(
            vec![(s.clone(), b"payload".to_vec())],
            Duration::ZERO, // no repair loop: deterministic count
            Arc::clone(&metrics),
        )
        .unwrap();
        // the first snapshot happens before the interval gate, so wait
        // for it rather than for a full period
        let mut ok = false;
        for _ in 0..100 {
            if metrics.snapshot().snapshots >= 1 {
                ok = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(ok, "no snapshot after spawn");
        snapper.shutdown();
        let loaded = s.load_latest().unwrap().unwrap();
        assert_eq!(loaded.payload, b"payload");
        fs::remove_dir_all(s.dir()).ok();
    }

    #[test]
    fn repair_republishes_after_state_dir_wipe() {
        let s = store("repair");
        let metrics = Arc::new(Metrics::new());
        let snapper = Snapshotter::spawn(
            vec![(s.clone(), b"durable".to_vec())],
            Duration::from_millis(100),
            Arc::clone(&metrics),
        )
        .unwrap();
        // wait for the initial snapshot, then wipe the state dir
        for _ in 0..100 {
            if metrics.snapshot().snapshots >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        fs::remove_dir_all(s.dir()).unwrap();
        // the repair loop must notice and re-publish
        let mut ok = false;
        for _ in 0..100 {
            if s.load_latest().ok().flatten().is_some() {
                ok = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(ok, "snapshot not re-published after wipe");
        assert!(metrics.snapshot().snapshots >= 2);
        snapper.shutdown();
        fs::remove_dir_all(s.dir()).ok();
    }

    #[test]
    fn valid_generation_is_left_alone() {
        let s = store("leave");
        let metrics = Arc::new(Metrics::new());
        let snapper = Snapshotter::spawn(
            vec![(s.clone(), b"stable".to_vec())],
            Duration::from_millis(60),
            Arc::clone(&metrics),
        )
        .unwrap();
        // several repair periods pass; only the initial publish counts
        std::thread::sleep(Duration::from_millis(400));
        snapper.shutdown();
        assert_eq!(metrics.snapshot().snapshots, 1);
        assert_eq!(s.generations().unwrap().len(), 1);
        fs::remove_dir_all(s.dir()).ok();
    }
}
