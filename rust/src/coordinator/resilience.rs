//! Resilience primitives for the serving coordinator: retry policies
//! with exponential backoff, per-engine circuit breakers, request-scoped
//! deadline budgets, and the error taxonomy that decides which failures
//! are worth retrying or falling back on.
//!
//! The router composes these into a degradation ladder: a failing
//! engine is retried (transient faults), then its breaker absorbs the
//! failure (consecutive faults trip it open), and the request falls
//! through the fallback chain until an engine answers — with every
//! retry, backoff sleep, and fallback hop drawing from one shared
//! [`Budget`] instead of each attempt getting a fresh deadline. An open
//! breaker lets a single half-open probe through after a cooldown, and
//! only closes again after `probe_successes` consecutive probes pass,
//! so a flapping engine cannot rejoin the chain off one lucky call.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::ResilienceConfig;
use crate::error::AsnnError;

/// Retry-with-backoff policy for transient engine failures.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Additional attempts after the first (0 = no retries).
    pub max_retries: u32,
    /// Backoff before the first retry; doubles each further attempt.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_retries: 0, backoff: Duration::from_micros(500) }
    }
}

impl RetryPolicy {
    /// Backoff to sleep before retry number `attempt` (0-based).
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        self.backoff.saturating_mul(1u32 << attempt.min(16))
    }
}

/// Request-scoped deadline budget: one clock the whole request draws
/// from, shared by every retry, backoff sleep, fallback hop, and hedge.
/// `Copy` (it is an `Instant` plus a cap) so it can be handed to
/// detached attempt threads while all of them measure the same window.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    started: Instant,
    total: Option<Duration>,
}

impl Budget {
    /// Budget capped at `total`; `None` never expires.
    pub fn start(total: Option<Duration>) -> Self {
        Self { started: Instant::now(), total }
    }

    /// A budget that never expires (per-attempt deadlines still apply).
    pub fn unlimited() -> Self {
        Self::start(None)
    }

    pub fn total(&self) -> Option<Duration> {
        self.total
    }

    /// Time left before the budget expires (`None` = unbounded).
    pub fn remaining(&self) -> Option<Duration> {
        self.total.map(|t| t.saturating_sub(self.started.elapsed()))
    }

    pub fn expired(&self) -> bool {
        matches!(self.remaining(), Some(r) if r.is_zero())
    }

    /// Clamp a per-attempt deadline to what is left of the budget; with
    /// no per-attempt deadline the remaining budget *is* the deadline,
    /// so a budget bounds engines even when `deadline_ms` is off.
    pub fn clamp(&self, deadline: Option<Duration>) -> Option<Duration> {
        match (deadline, self.remaining()) {
            (Some(d), Some(r)) => Some(d.min(r)),
            (Some(d), None) => Some(d),
            (None, r) => r,
        }
    }
}

/// Circuit-breaker tuning.
#[derive(Debug, Clone, Copy)]
pub struct BreakerPolicy {
    /// Consecutive failures that trip the breaker open.
    pub threshold: u32,
    /// How long the breaker stays open before a half-open probe.
    pub cooldown: Duration,
    /// Consecutive half-open probe successes required to close again
    /// (1 = close on the first success, the classic behaviour).
    pub probe_successes: u32,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        Self { threshold: 5, cooldown: Duration::from_secs(1), probe_successes: 1 }
    }
}

/// Observable breaker state (for HEALTH probes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn name(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

#[derive(Debug)]
enum Inner {
    Closed {
        consecutive_failures: u32,
    },
    Open {
        since: Instant,
    },
    /// Probing: `successes` consecutive probes have passed so far;
    /// `probe_inflight` serializes probes (one at a time), and `since`
    /// lets a lost probe expire after a full cooldown.
    HalfOpen {
        since: Instant,
        successes: u32,
        probe_inflight: bool,
    },
}

/// Per-engine circuit breaker. All methods take `&self`; state lives
/// behind a mutex and every transition is a single short critical
/// section, so the breaker is safe to share across worker threads.
#[derive(Debug)]
pub struct CircuitBreaker {
    policy: BreakerPolicy,
    inner: Mutex<Inner>,
}

impl CircuitBreaker {
    pub fn new(policy: BreakerPolicy) -> Self {
        Self { policy, inner: Mutex::new(Inner::Closed { consecutive_failures: 0 }) }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// May this request use the guarded engine right now? An open
    /// breaker admits one probe per cooldown window, and a half-open
    /// breaker admits the next probe only once the previous one has
    /// been resolved (or presumed lost after a full cooldown).
    pub fn allow(&self) -> bool {
        let mut g = self.lock();
        match &mut *g {
            Inner::Closed { .. } => true,
            Inner::Open { since } => {
                if since.elapsed() >= self.policy.cooldown {
                    *g = Inner::HalfOpen {
                        since: Instant::now(),
                        successes: 0,
                        probe_inflight: true,
                    };
                    true
                } else {
                    false
                }
            }
            Inner::HalfOpen { since, probe_inflight, .. } => {
                if !*probe_inflight {
                    *probe_inflight = true;
                    *since = Instant::now();
                    true
                } else if since.elapsed() >= self.policy.cooldown {
                    // probe presumed lost after a full cooldown: allow
                    // another (earned successes are kept — a lost probe
                    // is not a failure)
                    *since = Instant::now();
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Non-mutating admission peek: would `allow` currently grant a
    /// request? Used by the router's hedging logic to check whether a
    /// further engine is worth waiting for *without* consuming that
    /// engine's probe slot.
    pub fn would_allow(&self) -> bool {
        match &*self.lock() {
            Inner::Closed { .. } => true,
            Inner::Open { since } => since.elapsed() >= self.policy.cooldown,
            Inner::HalfOpen { since, probe_inflight, .. } => {
                !*probe_inflight || since.elapsed() >= self.policy.cooldown
            }
        }
    }

    /// Record a success. Closed: reset the failure count. Half-open:
    /// credit the probe; the breaker closes only after
    /// `probe_successes` consecutive probes pass. Open (a late or
    /// hedged attempt succeeding after the trip): start a half-open
    /// window with one credit rather than snapping closed.
    pub fn record_success(&self) {
        let mut g = self.lock();
        match &mut *g {
            Inner::Closed { consecutive_failures } => *consecutive_failures = 0,
            Inner::Open { .. } => {
                if self.policy.probe_successes <= 1 {
                    *g = Inner::Closed { consecutive_failures: 0 };
                } else {
                    *g = Inner::HalfOpen {
                        since: Instant::now(),
                        successes: 1,
                        probe_inflight: false,
                    };
                }
            }
            Inner::HalfOpen { successes, probe_inflight, .. } => {
                *probe_inflight = false;
                *successes += 1;
                if *successes >= self.policy.probe_successes {
                    *g = Inner::Closed { consecutive_failures: 0 };
                }
            }
        }
    }

    /// Record a failure; returns `true` when this failure trips the
    /// breaker open (closed → open or a failed half-open probe).
    pub fn record_failure(&self) -> bool {
        let mut g = self.lock();
        match &mut *g {
            Inner::Closed { consecutive_failures } => {
                *consecutive_failures += 1;
                if *consecutive_failures >= self.policy.threshold {
                    *g = Inner::Open { since: Instant::now() };
                    true
                } else {
                    false
                }
            }
            Inner::HalfOpen { .. } => {
                *g = Inner::Open { since: Instant::now() };
                true
            }
            Inner::Open { .. } => false,
        }
    }

    /// Non-mutating peek (an expired cooldown still reports `Open`
    /// until a request actually probes it).
    pub fn state(&self) -> BreakerState {
        match &*self.lock() {
            Inner::Closed { .. } => BreakerState::Closed,
            Inner::Open { .. } => BreakerState::Open,
            Inner::HalfOpen { .. } => BreakerState::HalfOpen,
        }
    }

    pub fn is_open(&self) -> bool {
        self.state() == BreakerState::Open
    }

    pub fn state_name(&self) -> &'static str {
        self.state().name()
    }
}

/// The router's full resilience policy.
#[derive(Debug, Clone, Copy)]
pub struct ResiliencePolicy {
    /// Per-attempt engine deadline; `None` disables deadline guarding
    /// (the engine call then runs inline on the worker thread unless a
    /// budget bounds it).
    pub deadline: Option<Duration>,
    /// Request-scoped budget covering retries, backoff, fallback hops,
    /// and hedges; `None` disables budgeting.
    pub budget: Option<Duration>,
    /// Fire the same query at the next healthy fallback engine after
    /// this long without an answer; `None` disables hedging.
    pub hedge_delay: Option<Duration>,
    pub retry: RetryPolicy,
    pub breaker: BreakerPolicy,
    /// Whether engine failures fall through the fallback chain.
    pub fallback_enabled: bool,
}

impl Default for ResiliencePolicy {
    fn default() -> Self {
        Self {
            deadline: None,
            budget: None,
            hedge_delay: None,
            retry: RetryPolicy::default(),
            breaker: BreakerPolicy::default(),
            fallback_enabled: true,
        }
    }
}

impl ResiliencePolicy {
    /// Build from the `[resilience]` config section.
    pub fn from_config(cfg: &ResilienceConfig) -> Self {
        Self {
            deadline: (cfg.deadline_ms > 0).then(|| Duration::from_millis(cfg.deadline_ms)),
            budget: (cfg.budget_ms > 0).then(|| Duration::from_millis(cfg.budget_ms)),
            hedge_delay: (cfg.hedge_delay_ms > 0)
                .then(|| Duration::from_millis(cfg.hedge_delay_ms)),
            retry: RetryPolicy {
                max_retries: cfg.retry_max,
                backoff: Duration::from_micros(cfg.retry_backoff_us),
            },
            breaker: BreakerPolicy {
                threshold: cfg.breaker_threshold,
                cooldown: Duration::from_millis(cfg.breaker_cooldown_ms),
                probe_successes: cfg.probe_successes,
            },
            fallback_enabled: cfg.fallback,
        }
    }
}

/// Errors caused by the request itself: no engine will do better, so
/// they are returned immediately without retry, breaker penalty, or
/// fallback.
pub fn is_client_error(e: &AsnnError) -> bool {
    matches!(e, AsnnError::Query(_) | AsnnError::Protocol(_) | AsnnError::Config(_))
}

/// Errors worth retrying on the same engine (transient runtime / I/O
/// faults). Timeouts are deliberately not retryable: the engine is
/// already slower than the budget, so the request falls back instead.
pub fn is_retryable(e: &AsnnError) -> bool {
    matches!(e, AsnnError::Runtime(_) | AsnnError::Io(_) | AsnnError::Coordinator(_))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    fn policy(threshold: u32, cooldown_ms: u64) -> BreakerPolicy {
        BreakerPolicy {
            threshold,
            cooldown: Duration::from_millis(cooldown_ms),
            probe_successes: 1,
        }
    }

    #[test]
    fn trips_after_consecutive_failures() {
        let b = CircuitBreaker::new(policy(3, 1000));
        assert!(!b.record_failure());
        assert!(!b.record_failure());
        assert!(b.record_failure()); // third failure trips
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow());
        assert!(!b.record_failure()); // already open: no second trip
    }

    #[test]
    fn success_resets_failure_count() {
        let b = CircuitBreaker::new(policy(2, 1000));
        assert!(!b.record_failure());
        b.record_success();
        assert!(!b.record_failure()); // count restarted
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn half_open_probe_after_cooldown() {
        let b = CircuitBreaker::new(policy(1, 20));
        assert!(b.record_failure());
        assert!(!b.allow()); // still cooling down
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.allow()); // the probe
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allow()); // only one probe per window
    }

    #[test]
    fn probe_outcome_closes_or_reopens() {
        let b = CircuitBreaker::new(policy(1, 10));
        b.record_failure();
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.allow());
        assert!(b.record_failure()); // failed probe re-trips
        assert_eq!(b.state(), BreakerState::Open);

        std::thread::sleep(Duration::from_millis(15));
        assert!(b.allow());
        b.record_success(); // healed (probe_successes = 1)
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow());
    }

    #[test]
    fn half_open_requires_success_window_to_close() {
        let b = CircuitBreaker::new(BreakerPolicy {
            threshold: 1,
            cooldown: Duration::from_millis(10),
            probe_successes: 3,
        });
        assert!(b.record_failure());
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.allow()); // probe 1
        b.record_success();
        assert_eq!(b.state(), BreakerState::HalfOpen); // 1 of 3
        assert!(b.allow()); // next probe admitted right after a success
        b.record_success();
        assert_eq!(b.state(), BreakerState::HalfOpen); // 2 of 3
        assert!(b.allow());
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed); // window complete
    }

    #[test]
    fn half_open_window_failure_reopens_and_resets_credit() {
        let b = CircuitBreaker::new(BreakerPolicy {
            threshold: 1,
            cooldown: Duration::from_millis(10),
            probe_successes: 2,
        });
        assert!(b.record_failure());
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.allow());
        b.record_success(); // 1 of 2
        assert!(b.allow());
        assert!(b.record_failure()); // probe fails: back to open, credit lost
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.allow());
        b.record_success();
        assert_eq!(b.state(), BreakerState::HalfOpen); // fresh window: 1 of 2
        assert!(b.allow());
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn would_allow_does_not_consume_the_probe() {
        let b = CircuitBreaker::new(policy(1, 10));
        assert!(b.would_allow());
        b.record_failure();
        assert!(!b.would_allow());
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.would_allow());
        assert!(b.would_allow()); // peeking twice is fine
        assert_eq!(b.state(), BreakerState::Open); // still open: no probe spent
        assert!(b.allow()); // the actual probe is still available
        assert!(!b.would_allow()); // now it is in flight
    }

    #[test]
    fn breaker_concurrent_hammer_conserves_trips() {
        // N threads race record_failure from Closed (threshold 1):
        // exactly one must observe the trip, every round, or the trips
        // counter in metrics would drift from reality.
        let b = Arc::new(CircuitBreaker::new(policy(1, 60_000)));
        let trips = Arc::new(AtomicU64::new(0));
        for _round in 0..50 {
            let threads: Vec<_> = (0..8)
                .map(|_| {
                    let b = Arc::clone(&b);
                    let trips = Arc::clone(&trips);
                    std::thread::spawn(move || {
                        if b.record_failure() {
                            trips.fetch_add(1, Ordering::SeqCst);
                        }
                        // hammer allow too: cooldown is a minute out,
                        // so nothing may be admitted here
                        assert!(!b.allow());
                    })
                })
                .collect();
            for t in threads {
                t.join().unwrap();
            }
            assert_eq!(b.state(), BreakerState::Open);
            b.record_success(); // heal for the next round
            assert_eq!(b.state(), BreakerState::Closed);
        }
        assert_eq!(trips.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn breaker_concurrent_allow_admits_one_probe_per_window() {
        let b = Arc::new(CircuitBreaker::new(policy(1, 200)));
        assert!(b.record_failure());
        std::thread::sleep(Duration::from_millis(210));
        let admitted = Arc::new(AtomicU64::new(0));
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let b = Arc::clone(&b);
                let admitted = Arc::clone(&admitted);
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        if b.allow() {
                            admitted.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // the unresolved probe blocks further admissions until a full
        // cooldown passes, which is far longer than the hammer loop
        assert_eq!(admitted.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn backoff_doubles_per_attempt() {
        let r = RetryPolicy { max_retries: 3, backoff: Duration::from_millis(2) };
        assert_eq!(r.backoff_for(0), Duration::from_millis(2));
        assert_eq!(r.backoff_for(1), Duration::from_millis(4));
        assert_eq!(r.backoff_for(2), Duration::from_millis(8));
    }

    #[test]
    fn budget_tracks_remaining_and_expiry() {
        let b = Budget::start(Some(Duration::from_millis(50)));
        assert!(!b.expired());
        assert!(b.remaining().unwrap() <= Duration::from_millis(50));
        std::thread::sleep(Duration::from_millis(60));
        assert!(b.expired());
        assert_eq!(b.remaining(), Some(Duration::ZERO));

        let unlimited = Budget::unlimited();
        assert!(!unlimited.expired());
        assert_eq!(unlimited.remaining(), None);
    }

    #[test]
    fn budget_clamps_attempt_deadlines() {
        let b = Budget::start(Some(Duration::from_secs(10)));
        // per-attempt deadline shorter than the budget: unchanged
        assert_eq!(b.clamp(Some(Duration::from_millis(5))), Some(Duration::from_millis(5)));
        // per-attempt deadline longer than the budget: clamped down
        let clamped = b.clamp(Some(Duration::from_secs(60))).unwrap();
        assert!(clamped <= Duration::from_secs(10));
        // no per-attempt deadline: the remaining budget is the deadline
        assert!(b.clamp(None).unwrap() <= Duration::from_secs(10));
        // no budget either: fully unbounded
        assert_eq!(Budget::unlimited().clamp(None), None);
    }

    #[test]
    fn error_taxonomy() {
        assert!(is_client_error(&AsnnError::Query("k=0".into())));
        assert!(!is_client_error(&AsnnError::Runtime("pjrt".into())));
        assert!(is_retryable(&AsnnError::Runtime("pjrt".into())));
        assert!(!is_retryable(&AsnnError::Timeout("slow".into())));
        assert!(!is_retryable(&AsnnError::Query("k=0".into())));
    }

    #[test]
    fn policy_from_config() {
        let cfg = ResilienceConfig {
            deadline_ms: 250,
            budget_ms: 800,
            hedge_delay_ms: 30,
            max_inflight: 64,
            retry_max: 2,
            retry_backoff_us: 100,
            breaker_threshold: 7,
            breaker_cooldown_ms: 500,
            probe_successes: 3,
            drain_deadline_ms: 750,
            fallback: false,
        };
        let p = ResiliencePolicy::from_config(&cfg);
        assert_eq!(p.deadline, Some(Duration::from_millis(250)));
        assert_eq!(p.budget, Some(Duration::from_millis(800)));
        assert_eq!(p.hedge_delay, Some(Duration::from_millis(30)));
        assert_eq!(p.retry.max_retries, 2);
        assert_eq!(p.breaker.threshold, 7);
        assert_eq!(p.breaker.probe_successes, 3);
        assert!(!p.fallback_enabled);
        let disabled =
            ResilienceConfig { deadline_ms: 0, budget_ms: 0, hedge_delay_ms: 0, ..cfg };
        let p = ResiliencePolicy::from_config(&disabled);
        assert_eq!(p.deadline, None);
        assert_eq!(p.budget, None);
        assert_eq!(p.hedge_delay, None);
    }
}
