//! Resilience primitives for the serving coordinator: retry policies
//! with exponential backoff, per-engine circuit breakers, and the
//! error taxonomy that decides which failures are worth retrying or
//! falling back on.
//!
//! The router composes these into a degradation ladder: a failing
//! engine is retried (transient faults), then its breaker absorbs the
//! failure (consecutive faults trip it open), and the request falls
//! through the fallback chain until an engine answers. An open breaker
//! lets a single half-open probe through after a cooldown, so a healed
//! engine rejoins the chain without a thundering herd.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::ResilienceConfig;
use crate::error::AsnnError;

/// Retry-with-backoff policy for transient engine failures.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Additional attempts after the first (0 = no retries).
    pub max_retries: u32,
    /// Backoff before the first retry; doubles each further attempt.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_retries: 0, backoff: Duration::from_micros(500) }
    }
}

impl RetryPolicy {
    /// Backoff to sleep before retry number `attempt` (0-based).
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        self.backoff.saturating_mul(1u32 << attempt.min(16))
    }
}

/// Circuit-breaker tuning.
#[derive(Debug, Clone, Copy)]
pub struct BreakerPolicy {
    /// Consecutive failures that trip the breaker open.
    pub threshold: u32,
    /// How long the breaker stays open before a half-open probe.
    pub cooldown: Duration,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        Self { threshold: 5, cooldown: Duration::from_secs(1) }
    }
}

/// Observable breaker state (for HEALTH probes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn name(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

#[derive(Debug)]
enum Inner {
    Closed { consecutive_failures: u32 },
    Open { since: Instant },
    /// A probe request is in flight; `since` lets a lost probe expire.
    HalfOpen { since: Instant },
}

/// Per-engine circuit breaker. All methods take `&self`; state lives
/// behind a mutex and every transition is a single short critical
/// section, so the breaker is safe to share across worker threads.
#[derive(Debug)]
pub struct CircuitBreaker {
    policy: BreakerPolicy,
    inner: Mutex<Inner>,
}

impl CircuitBreaker {
    pub fn new(policy: BreakerPolicy) -> Self {
        Self { policy, inner: Mutex::new(Inner::Closed { consecutive_failures: 0 }) }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// May this request use the guarded engine right now? An open
    /// breaker admits one probe per cooldown window.
    pub fn allow(&self) -> bool {
        let mut g = self.lock();
        match &*g {
            Inner::Closed { .. } => true,
            Inner::Open { since } => {
                if since.elapsed() >= self.policy.cooldown {
                    *g = Inner::HalfOpen { since: Instant::now() };
                    true
                } else {
                    false
                }
            }
            Inner::HalfOpen { since } => {
                // probe presumed lost after a full cooldown: allow another
                if since.elapsed() >= self.policy.cooldown {
                    *g = Inner::HalfOpen { since: Instant::now() };
                    true
                } else {
                    false
                }
            }
        }
    }

    pub fn record_success(&self) {
        *self.lock() = Inner::Closed { consecutive_failures: 0 };
    }

    /// Record a failure; returns `true` when this failure trips the
    /// breaker open (closed → open or a failed half-open probe).
    pub fn record_failure(&self) -> bool {
        let mut g = self.lock();
        match &mut *g {
            Inner::Closed { consecutive_failures } => {
                *consecutive_failures += 1;
                if *consecutive_failures >= self.policy.threshold {
                    *g = Inner::Open { since: Instant::now() };
                    true
                } else {
                    false
                }
            }
            Inner::HalfOpen { .. } => {
                *g = Inner::Open { since: Instant::now() };
                true
            }
            Inner::Open { .. } => false,
        }
    }

    /// Non-mutating peek (an expired cooldown still reports `Open`
    /// until a request actually probes it).
    pub fn state(&self) -> BreakerState {
        match &*self.lock() {
            Inner::Closed { .. } => BreakerState::Closed,
            Inner::Open { .. } => BreakerState::Open,
            Inner::HalfOpen { .. } => BreakerState::HalfOpen,
        }
    }

    pub fn is_open(&self) -> bool {
        self.state() == BreakerState::Open
    }

    pub fn state_name(&self) -> &'static str {
        self.state().name()
    }
}

/// The router's full resilience policy.
#[derive(Debug, Clone, Copy)]
pub struct ResiliencePolicy {
    /// Per-attempt engine deadline; `None` disables deadline guarding
    /// (the engine call then runs inline on the worker thread).
    pub deadline: Option<Duration>,
    pub retry: RetryPolicy,
    pub breaker: BreakerPolicy,
    /// Whether engine failures fall through the fallback chain.
    pub fallback_enabled: bool,
}

impl Default for ResiliencePolicy {
    fn default() -> Self {
        Self {
            deadline: None,
            retry: RetryPolicy::default(),
            breaker: BreakerPolicy::default(),
            fallback_enabled: true,
        }
    }
}

impl ResiliencePolicy {
    /// Build from the `[resilience]` config section.
    pub fn from_config(cfg: &ResilienceConfig) -> Self {
        Self {
            deadline: (cfg.deadline_ms > 0).then(|| Duration::from_millis(cfg.deadline_ms)),
            retry: RetryPolicy {
                max_retries: cfg.retry_max,
                backoff: Duration::from_micros(cfg.retry_backoff_us),
            },
            breaker: BreakerPolicy {
                threshold: cfg.breaker_threshold,
                cooldown: Duration::from_millis(cfg.breaker_cooldown_ms),
            },
            fallback_enabled: cfg.fallback,
        }
    }
}

/// Errors caused by the request itself: no engine will do better, so
/// they are returned immediately without retry, breaker penalty, or
/// fallback.
pub fn is_client_error(e: &AsnnError) -> bool {
    matches!(e, AsnnError::Query(_) | AsnnError::Protocol(_) | AsnnError::Config(_))
}

/// Errors worth retrying on the same engine (transient runtime / I/O
/// faults). Timeouts are deliberately not retryable: the engine is
/// already slower than the budget, so the request falls back instead.
pub fn is_retryable(e: &AsnnError) -> bool {
    matches!(e, AsnnError::Runtime(_) | AsnnError::Io(_) | AsnnError::Coordinator(_))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(threshold: u32, cooldown_ms: u64) -> BreakerPolicy {
        BreakerPolicy { threshold, cooldown: Duration::from_millis(cooldown_ms) }
    }

    #[test]
    fn trips_after_consecutive_failures() {
        let b = CircuitBreaker::new(policy(3, 1000));
        assert!(!b.record_failure());
        assert!(!b.record_failure());
        assert!(b.record_failure()); // third failure trips
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow());
        assert!(!b.record_failure()); // already open: no second trip
    }

    #[test]
    fn success_resets_failure_count() {
        let b = CircuitBreaker::new(policy(2, 1000));
        assert!(!b.record_failure());
        b.record_success();
        assert!(!b.record_failure()); // count restarted
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn half_open_probe_after_cooldown() {
        let b = CircuitBreaker::new(policy(1, 20));
        assert!(b.record_failure());
        assert!(!b.allow()); // still cooling down
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.allow()); // the probe
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allow()); // only one probe per window
    }

    #[test]
    fn probe_outcome_closes_or_reopens() {
        let b = CircuitBreaker::new(policy(1, 10));
        b.record_failure();
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.allow());
        assert!(b.record_failure()); // failed probe re-trips
        assert_eq!(b.state(), BreakerState::Open);

        std::thread::sleep(Duration::from_millis(15));
        assert!(b.allow());
        b.record_success(); // healed
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow());
    }

    #[test]
    fn backoff_doubles_per_attempt() {
        let r = RetryPolicy { max_retries: 3, backoff: Duration::from_millis(2) };
        assert_eq!(r.backoff_for(0), Duration::from_millis(2));
        assert_eq!(r.backoff_for(1), Duration::from_millis(4));
        assert_eq!(r.backoff_for(2), Duration::from_millis(8));
    }

    #[test]
    fn error_taxonomy() {
        assert!(is_client_error(&AsnnError::Query("k=0".into())));
        assert!(!is_client_error(&AsnnError::Runtime("pjrt".into())));
        assert!(is_retryable(&AsnnError::Runtime("pjrt".into())));
        assert!(!is_retryable(&AsnnError::Timeout("slow".into())));
        assert!(!is_retryable(&AsnnError::Query("k=0".into())));
    }

    #[test]
    fn policy_from_config() {
        let cfg = ResilienceConfig {
            deadline_ms: 250,
            max_inflight: 64,
            retry_max: 2,
            retry_backoff_us: 100,
            breaker_threshold: 7,
            breaker_cooldown_ms: 500,
            fallback: false,
        };
        let p = ResiliencePolicy::from_config(&cfg);
        assert_eq!(p.deadline, Some(Duration::from_millis(250)));
        assert_eq!(p.retry.max_retries, 2);
        assert_eq!(p.breaker.threshold, 7);
        assert!(!p.fallback_enabled);
        let disabled = ResilienceConfig { deadline_ms: 0, ..cfg };
        assert_eq!(ResiliencePolicy::from_config(&disabled).deadline, None);
    }
}
