//! Fixed-size thread pool (std-only) for connection handling, with
//! panic isolation: a panicking job is caught with `catch_unwind` and
//! counted, degrading that one request instead of killing the worker
//! and silently shrinking the pool. A respawn guard backstops the
//! catch — if a panic ever does escape (e.g. a panic raised while the
//! payload's `Drop` unwinds), the dying worker spawns its replacement
//! and the respawn is counted.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::error::{AsnnError, Result};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Called (from the worker thread) each time a job panic is caught;
/// lets the server feed pool panics into its metrics.
pub type PanicObserver = Arc<dyn Fn() + Send + Sync>;

struct PoolShared {
    rx: Mutex<Receiver<Job>>,
    panics: AtomicU64,
    respawns: AtomicU64,
    observer: Option<PanicObserver>,
}

/// A basic fixed thread pool; jobs are closures.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    shared: Arc<PoolShared>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        Self::build(threads, None)
    }

    /// Pool whose caught-panic events are reported to `observer`.
    pub fn with_observer(threads: usize, observer: PanicObserver) -> Self {
        Self::build(threads, Some(observer))
    }

    fn build(threads: usize, observer: Option<PanicObserver>) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let shared = Arc::new(PoolShared {
            rx: Mutex::new(rx),
            panics: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            observer,
        });
        let handles = (0..threads)
            .map(|i| spawn_worker(i, Arc::clone(&shared)).expect("spawn worker"))
            .collect();
        Self { tx: Some(tx), handles, shared }
    }

    /// Queue a job. Errors (instead of panicking) if the pool has shut
    /// down, so a shutdown racing the accept loop can't crash the
    /// server.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| AsnnError::Coordinator("thread pool shut down".into()))?;
        tx.send(Box::new(job))
            .map_err(|_| AsnnError::Coordinator("worker channel closed".into()))
    }

    /// Close the queue and join the original workers. Subsequent
    /// `execute` calls return an error. Idempotent.
    pub fn shutdown(&mut self) {
        drop(self.tx.take()); // close the channel
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }

    /// Job panics caught (and survived) so far.
    pub fn panics_caught(&self) -> u64 {
        self.shared.panics.load(Ordering::Relaxed)
    }

    /// Workers respawned after an escaped panic (0 in normal operation).
    pub fn respawns(&self) -> u64 {
        self.shared.respawns.load(Ordering::Relaxed)
    }
}

/// Fallible so the respawn path (which runs during a panic unwind)
/// can swallow a spawn failure instead of aborting the process with a
/// double panic; pool construction still expects success.
fn spawn_worker(idx: usize, shared: Arc<PoolShared>) -> std::io::Result<JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("asnn-worker-{idx}"))
        .spawn(move || worker_loop(idx, shared))
}

/// Backstop for panics that escape `catch_unwind`: if the worker
/// thread unwinds, spawn a replacement so the pool keeps its size.
/// Replacements are detached (they exit when the channel closes).
struct RespawnGuard {
    idx: usize,
    shared: Arc<PoolShared>,
    armed: bool,
}

impl Drop for RespawnGuard {
    fn drop(&mut self) {
        if self.armed && std::thread::panicking() {
            self.shared.respawns.fetch_add(1, Ordering::Relaxed);
            let _ = spawn_worker(self.idx, Arc::clone(&self.shared));
        }
    }
}

fn worker_loop(idx: usize, shared: Arc<PoolShared>) {
    let mut guard = RespawnGuard { idx, shared: Arc::clone(&shared), armed: true };
    loop {
        let job = {
            // recover the receiver even if a previous holder panicked
            let rx = shared.rx.lock().unwrap_or_else(|p| p.into_inner());
            rx.recv()
        };
        match job {
            Ok(job) => {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    shared.panics.fetch_add(1, Ordering::Relaxed);
                    if let Some(obs) = &shared.observer {
                        obs();
                    }
                }
            }
            Err(_) => break, // all senders dropped: shutdown
        }
    }
    guard.armed = false; // clean exit: no respawn
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = ThreadPool::new(2);
        let (tx, rx) = channel();
        let t0 = std::time::Instant::now();
        for _ in 0..2 {
            let tx = tx.clone();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                tx.send(()).unwrap();
            })
            .unwrap();
        }
        rx.recv().unwrap();
        rx.recv().unwrap();
        // two 50 ms jobs on two threads: well under 100 ms
        assert!(t0.elapsed().as_millis() < 95, "{:?}", t0.elapsed());
    }

    #[test]
    fn reports_thread_count() {
        assert_eq!(ThreadPool::new(3).threads(), 3);
    }

    #[test]
    fn pool_survives_panicking_jobs() {
        let mut pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..20 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                if i % 4 == 0 {
                    panic!("poisoned job {i}");
                }
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        pool.shutdown(); // drains the queue, joins workers
        // 5 of 20 jobs panic; the other 15 must still run
        assert_eq!(counter.load(Ordering::SeqCst), 15);
        assert_eq!(pool.panics_caught(), 5);
        assert_eq!(pool.respawns(), 0);
    }

    #[test]
    fn panics_are_counted_and_observed() {
        let observed = Arc::new(AtomicUsize::new(0));
        let obs = Arc::clone(&observed);
        let mut pool =
            ThreadPool::with_observer(1, Arc::new(move || {
                obs.fetch_add(1, Ordering::SeqCst);
            }));
        for _ in 0..3 {
            pool.execute(|| panic!("boom")).unwrap();
        }
        pool.shutdown(); // drains the queue, joins workers
        assert_eq!(pool.panics_caught(), 3);
        assert_eq!(observed.load(Ordering::SeqCst), 3);
        assert_eq!(pool.respawns(), 0); // catch_unwind held
    }

    #[test]
    fn execute_on_shut_down_pool_errors_instead_of_panicking() {
        let mut pool = ThreadPool::new(1);
        pool.execute(|| {}).unwrap();
        pool.shutdown();
        let err = pool.execute(|| {}).unwrap_err();
        assert_eq!(err.tag(), "coordinator");
        pool.shutdown(); // idempotent
    }
}
