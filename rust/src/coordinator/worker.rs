//! Fixed-size thread pool (std-only) for connection handling.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A basic fixed thread pool; jobs are closures.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("asnn-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), handles }
    }

    /// Queue a job. Panics if the pool is shut down.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    pub fn threads(&self) -> usize {
        self.handles.len()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(job) => job(),
            Err(_) => break, // all senders dropped: shutdown
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = ThreadPool::new(2);
        let (tx, rx) = channel();
        let t0 = std::time::Instant::now();
        for _ in 0..2 {
            let tx = tx.clone();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                tx.send(()).unwrap();
            });
        }
        rx.recv().unwrap();
        rx.recv().unwrap();
        // two 50 ms jobs on two threads: well under 100 ms
        assert!(t0.elapsed().as_millis() < 95, "{:?}", t0.elapsed());
    }

    #[test]
    fn reports_thread_count() {
        assert_eq!(ThreadPool::new(3).threads(), 3);
    }
}
