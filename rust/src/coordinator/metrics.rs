//! Serving metrics: request counters + latency histograms per verb.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::obs::Json;
use crate::util::stats::LatencyHistogram;

/// Shared metrics sink (cheap atomics on the hot path; the histogram
/// mutex is uncontended at this testbed's request rates).
#[derive(Debug, Default)]
pub struct Metrics {
    pub knn_requests: AtomicU64,
    pub classify_requests: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_queries: AtomicU64,
    /// Queries evicted from the batching lane because their request
    /// budget expired while queueing (published from the batcher's
    /// authoritative cumulative counter — store, not add).
    pub expired_dropped: AtomicU64,
    // resilience counters
    pub accept_errors: AtomicU64,
    pub shed: AtomicU64,
    pub timeouts: AtomicU64,
    pub retries: AtomicU64,
    pub breaker_trips: AtomicU64,
    pub fallbacks: AtomicU64,
    pub panics: AtomicU64,
    pub hedges: AtomicU64,
    pub hedge_wins: AtomicU64,
    pub budget_exhausted: AtomicU64,
    // hostile-input hardening counters
    pub oversize_rejected: AtomicU64,
    pub idle_disconnects: AtomicU64,
    pub write_timeout_disconnects: AtomicU64,
    // durability counters
    pub corrupt_quarantined: AtomicU64,
    pub snapshots: AtomicU64,
    pub snapshot_failures: AtomicU64,
    /// Gauge: connections admitted and not yet finished.
    inflight: AtomicU64,
    /// Gauge: server is draining (shutdown in progress, in-flight
    /// connections finishing up).
    draining: AtomicBool,
    /// Gauge: boot-time recovery in progress (state dir swept, warm
    /// snapshot being restored); HEALTH reports `status=recovering`.
    recovering: AtomicBool,
    knn_latency: Mutex<LatencyHistogram>,
    classify_latency: Mutex<LatencyHistogram>,
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub knn_requests: u64,
    pub classify_requests: u64,
    pub errors: u64,
    pub batches: u64,
    pub batched_queries: u64,
    pub expired_dropped: u64,
    pub accept_errors: u64,
    pub shed: u64,
    pub timeouts: u64,
    pub retries: u64,
    pub breaker_trips: u64,
    pub fallbacks: u64,
    pub panics: u64,
    pub hedges: u64,
    pub hedge_wins: u64,
    pub budget_exhausted: u64,
    pub oversize_rejected: u64,
    pub idle_disconnects: u64,
    pub write_timeout_disconnects: u64,
    pub corrupt_quarantined: u64,
    pub snapshots: u64,
    pub snapshot_failures: u64,
    pub knn_mean_us: f64,
    pub knn_p50_us: f64,
    pub knn_p99_us: f64,
    pub classify_mean_us: f64,
    pub classify_p99_us: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_knn(&self, ns: u64) {
        self.knn_requests.fetch_add(1, Ordering::Relaxed);
        self.knn_latency.lock().unwrap().record_ns(ns);
    }

    pub fn record_classify(&self, ns: u64) {
        self.classify_requests.fetch_add(1, Ordering::Relaxed);
        self.classify_latency.lock().unwrap().record_ns(ns);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_queries.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Sync the lane-eviction counter from the batcher's cumulative
    /// total (the batcher owns the count; metrics only mirror it).
    pub fn publish_expired_dropped(&self, total: u64) {
        self.expired_dropped.store(total, Ordering::Relaxed);
    }

    /// Failed `accept()` on the listener socket.
    pub fn record_accept_error(&self) {
        self.accept_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Connection rejected by admission control (queue full).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Engine call exceeded its per-request deadline.
    pub fn record_timeout(&self) {
        self.timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// Transient engine failure retried with backoff.
    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// A circuit breaker tripped open.
    pub fn record_trip(&self) {
        self.breaker_trips.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was served by a fallback engine, not the one asked for.
    pub fn record_fallback(&self) {
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// A panic caught and isolated (worker pool job or engine call).
    pub fn record_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// A hedge attempt fired at the next healthy fallback engine.
    pub fn record_hedge(&self) {
        self.hedges.fetch_add(1, Ordering::Relaxed);
    }

    /// A hedge attempt answered before the engine it was hedging.
    pub fn record_hedge_win(&self) {
        self.hedge_wins.fetch_add(1, Ordering::Relaxed);
    }

    /// A request ran out of its deadline budget before any engine
    /// answered.
    pub fn record_budget_exhausted(&self) {
        self.budget_exhausted.fetch_add(1, Ordering::Relaxed);
    }

    /// A request line exceeded `max_line_bytes` and was rejected with
    /// `ERR too-long` before buffering the rest.
    pub fn record_oversize_rejected(&self) {
        self.oversize_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// A connection sat idle past the idle deadline and was closed
    /// (slow-loris defense).
    pub fn record_idle_disconnect(&self) {
        self.idle_disconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// A response write timed out and the connection was dropped.
    pub fn record_write_timeout_disconnect(&self) {
        self.write_timeout_disconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// Corrupt snapshot/state files quarantined to `<path>.corrupt`.
    pub fn record_corrupt_quarantined(&self, n: u64) {
        self.corrupt_quarantined.fetch_add(n, Ordering::Relaxed);
    }

    /// A state snapshot generation was published.
    pub fn record_snapshot(&self) {
        self.snapshots.fetch_add(1, Ordering::Relaxed);
    }

    /// A state snapshot attempt failed (disk full, permissions, ...).
    pub fn record_snapshot_failure(&self) {
        self.snapshot_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Flip the drain gauge (set at shutdown start so HEALTH can report
    /// `status=draining` while in-flight connections finish).
    pub fn set_draining(&self, draining: bool) {
        self.draining.store(draining, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Flip the recovery gauge (set while boot-time recovery runs so
    /// HEALTH reports `status=recovering` until warm boot completes).
    pub fn set_recovering(&self, recovering: bool) {
        self.recovering.store(recovering, Ordering::SeqCst);
    }

    pub fn is_recovering(&self) -> bool {
        self.recovering.load(Ordering::SeqCst)
    }

    pub fn enter_inflight(&self) {
        self.inflight.fetch_add(1, Ordering::SeqCst);
    }

    pub fn exit_inflight(&self) {
        self.inflight.fetch_sub(1, Ordering::SeqCst);
    }

    /// Current admitted-but-unfinished connection count (queue depth).
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let knn = self.knn_latency.lock().unwrap().clone();
        let cls = self.classify_latency.lock().unwrap().clone();
        MetricsSnapshot {
            knn_requests: self.knn_requests.load(Ordering::Relaxed),
            classify_requests: self.classify_requests.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_queries: self.batched_queries.load(Ordering::Relaxed),
            expired_dropped: self.expired_dropped.load(Ordering::Relaxed),
            accept_errors: self.accept_errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            breaker_trips: self.breaker_trips.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            hedges: self.hedges.load(Ordering::Relaxed),
            hedge_wins: self.hedge_wins.load(Ordering::Relaxed),
            budget_exhausted: self.budget_exhausted.load(Ordering::Relaxed),
            oversize_rejected: self.oversize_rejected.load(Ordering::Relaxed),
            idle_disconnects: self.idle_disconnects.load(Ordering::Relaxed),
            write_timeout_disconnects: self.write_timeout_disconnects.load(Ordering::Relaxed),
            corrupt_quarantined: self.corrupt_quarantined.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            snapshot_failures: self.snapshot_failures.load(Ordering::Relaxed),
            knn_mean_us: knn.mean_ns() / 1e3,
            knn_p50_us: knn.quantile_ns(0.5) as f64 / 1e3,
            knn_p99_us: knn.quantile_ns(0.99) as f64 / 1e3,
            classify_mean_us: cls.mean_ns() / 1e3,
            classify_p99_us: cls.quantile_ns(0.99) as f64 / 1e3,
        }
    }
}

impl MetricsSnapshot {
    /// One-line rendering for the legacy STATS verb.
    ///
    /// FROZEN: this byte format is a compatibility contract. Scripts
    /// parse it field-by-field; never reorder, rename, or reformat
    /// existing fields (`stats_render_format_is_frozen` pins it).
    /// New telemetry goes in [`to_json`](Self::to_json) / `STATS2`.
    pub fn render(&self) -> String {
        format!(
            "knn={} classify={} errors={} batches={} batched={} \
             expired_dropped={} \
             accept_errors={} shed={} timeouts={} retries={} trips={} \
             fallbacks={} panics={} hedges={} hedge_wins={} \
             budget_exhausted={} \
             oversize_rejected={} idle_disconnects={} write_timeout_disconnects={} \
             corrupt_quarantined={} snapshots={} snapshot_failures={} \
             knn_mean_us={:.1} knn_p50_us={:.1} knn_p99_us={:.1} \
             classify_mean_us={:.1} classify_p99_us={:.1}",
            self.knn_requests,
            self.classify_requests,
            self.errors,
            self.batches,
            self.batched_queries,
            self.expired_dropped,
            self.accept_errors,
            self.shed,
            self.timeouts,
            self.retries,
            self.breaker_trips,
            self.fallbacks,
            self.panics,
            self.hedges,
            self.hedge_wins,
            self.budget_exhausted,
            self.oversize_rejected,
            self.idle_disconnects,
            self.write_timeout_disconnects,
            self.corrupt_quarantined,
            self.snapshots,
            self.snapshot_failures,
            self.knn_mean_us,
            self.knn_p50_us,
            self.knn_p99_us,
            self.classify_mean_us,
            self.classify_p99_us,
        )
    }

    /// Structured rendering for the `STATS2` coordinator section.
    /// Same counters as [`render`](Self::render), key-typed instead of
    /// packed into one line; safe to extend with new keys.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("knn_requests", Json::num_u64(self.knn_requests)),
            ("classify_requests", Json::num_u64(self.classify_requests)),
            ("errors", Json::num_u64(self.errors)),
            ("batches", Json::num_u64(self.batches)),
            ("batched_queries", Json::num_u64(self.batched_queries)),
            ("expired_dropped", Json::num_u64(self.expired_dropped)),
            ("accept_errors", Json::num_u64(self.accept_errors)),
            ("shed", Json::num_u64(self.shed)),
            ("timeouts", Json::num_u64(self.timeouts)),
            ("retries", Json::num_u64(self.retries)),
            ("breaker_trips", Json::num_u64(self.breaker_trips)),
            ("fallbacks", Json::num_u64(self.fallbacks)),
            ("panics", Json::num_u64(self.panics)),
            ("hedges", Json::num_u64(self.hedges)),
            ("hedge_wins", Json::num_u64(self.hedge_wins)),
            ("budget_exhausted", Json::num_u64(self.budget_exhausted)),
            ("oversize_rejected", Json::num_u64(self.oversize_rejected)),
            ("idle_disconnects", Json::num_u64(self.idle_disconnects)),
            (
                "write_timeout_disconnects",
                Json::num_u64(self.write_timeout_disconnects),
            ),
            ("corrupt_quarantined", Json::num_u64(self.corrupt_quarantined)),
            ("snapshots", Json::num_u64(self.snapshots)),
            ("snapshot_failures", Json::num_u64(self.snapshot_failures)),
            ("knn_mean_us", Json::Num(self.knn_mean_us)),
            ("knn_p50_us", Json::Num(self.knn_p50_us)),
            ("knn_p99_us", Json::Num(self.knn_p99_us)),
            ("classify_mean_us", Json::Num(self.classify_mean_us)),
            ("classify_p99_us", Json::Num(self.classify_p99_us)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_knn(1000);
        m.record_knn(2000);
        m.record_classify(500);
        m.record_error();
        m.record_batch(16);
        let s = m.snapshot();
        assert_eq!(s.knn_requests, 2);
        assert_eq!(s.classify_requests, 1);
        assert_eq!(s.errors, 1);
        assert_eq!(s.batches, 1);
        assert_eq!(s.batched_queries, 16);
        assert!((s.knn_mean_us - 1.5).abs() < 1e-9);
    }

    #[test]
    fn expired_dropped_has_store_semantics() {
        let m = Metrics::new();
        m.publish_expired_dropped(3);
        m.publish_expired_dropped(5); // cumulative total replaces, never adds
        let s = m.snapshot();
        assert_eq!(s.expired_dropped, 5);
        assert!(s.render().contains("expired_dropped=5"), "{}", s.render());
    }

    #[test]
    fn render_contains_all_fields() {
        let m = Metrics::new();
        m.record_knn(1_000_000);
        let text = m.snapshot().render();
        for field in ["knn=", "classify=", "errors=", "knn_p99_us="] {
            assert!(text.contains(field), "{text}");
        }
    }

    #[test]
    fn resilience_counters_and_gauge() {
        let m = Metrics::new();
        m.record_accept_error();
        m.record_shed();
        m.record_timeout();
        m.record_retry();
        m.record_retry();
        m.record_trip();
        m.record_fallback();
        m.record_panic();
        m.record_hedge();
        m.record_hedge();
        m.record_hedge_win();
        m.record_budget_exhausted();
        m.enter_inflight();
        m.enter_inflight();
        m.exit_inflight();
        let s = m.snapshot();
        assert_eq!(s.accept_errors, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.retries, 2);
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.fallbacks, 1);
        assert_eq!(s.panics, 1);
        assert_eq!(s.hedges, 2);
        assert_eq!(s.hedge_wins, 1);
        assert_eq!(s.budget_exhausted, 1);
        assert_eq!(m.inflight(), 1);
        let text = s.render();
        for field in [
            "shed=1",
            "timeouts=1",
            "trips=1",
            "fallbacks=1",
            "panics=1",
            "hedges=2",
            "hedge_wins=1",
            "budget_exhausted=1",
        ] {
            assert!(text.contains(field), "{text}");
        }
    }

    #[test]
    fn hardening_and_durability_counters() {
        let m = Metrics::new();
        m.record_oversize_rejected();
        m.record_idle_disconnect();
        m.record_idle_disconnect();
        m.record_write_timeout_disconnect();
        m.record_corrupt_quarantined(3);
        m.record_snapshot();
        m.record_snapshot_failure();
        let s = m.snapshot();
        assert_eq!(s.oversize_rejected, 1);
        assert_eq!(s.idle_disconnects, 2);
        assert_eq!(s.write_timeout_disconnects, 1);
        assert_eq!(s.corrupt_quarantined, 3);
        assert_eq!(s.snapshots, 1);
        assert_eq!(s.snapshot_failures, 1);
        let text = s.render();
        for field in [
            "oversize_rejected=1",
            "idle_disconnects=2",
            "write_timeout_disconnects=1",
            "corrupt_quarantined=3",
            "snapshots=1",
            "snapshot_failures=1",
        ] {
            assert!(text.contains(field), "{text}");
        }
    }

    #[test]
    fn recovering_gauge_flips() {
        let m = Metrics::new();
        assert!(!m.is_recovering());
        m.set_recovering(true);
        assert!(m.is_recovering());
        m.set_recovering(false);
        assert!(!m.is_recovering());
    }

    #[test]
    fn draining_gauge_flips() {
        let m = Metrics::new();
        assert!(!m.is_draining());
        m.set_draining(true);
        assert!(m.is_draining());
        m.set_draining(false);
        assert!(!m.is_draining());
    }

    #[test]
    fn stats_render_format_is_frozen() {
        // byte-for-byte pin of the legacy STATS line — the shim
        // contract promised by docs/OBSERVABILITY.md. If this test
        // fails you have broken every script that parses STATS.
        let m = Metrics::new();
        m.record_knn(2_000); // 2 µs
        m.record_classify(4_000);
        m.record_error();
        m.record_batch(3);
        let line = m.snapshot().render();
        let expected = "knn=1 classify=1 errors=1 batches=1 batched=3 \
                        expired_dropped=0 \
                        accept_errors=0 shed=0 timeouts=0 retries=0 trips=0 \
                        fallbacks=0 panics=0 hedges=0 hedge_wins=0 \
                        budget_exhausted=0 \
                        oversize_rejected=0 idle_disconnects=0 write_timeout_disconnects=0 \
                        corrupt_quarantined=0 snapshots=0 snapshot_failures=0";
        assert!(line.starts_with(expected), "prefix diverged:\n{line}");
        // latency fields depend on histogram bucket edges — pin shape,
        // not values
        let tail: Vec<&str> = line[expected.len()..].split_whitespace().collect();
        let keys: Vec<&str> =
            tail.iter().map(|f| f.split_once('=').map(|(k, _)| k).unwrap_or(f)).collect();
        assert_eq!(
            keys,
            [
                "knn_mean_us",
                "knn_p50_us",
                "knn_p99_us",
                "classify_mean_us",
                "classify_p99_us"
            ],
            "{line}"
        );
        for f in &tail {
            let v = f.split_once('=').unwrap().1;
            assert!(v.parse::<f64>().is_ok(), "{f}");
            assert!(v.contains('.'), "{{:.1}} formatting changed: {f}");
        }
    }

    #[test]
    fn to_json_mirrors_render_counters() {
        let m = Metrics::new();
        m.record_knn(1_000);
        m.record_retry();
        m.record_retry();
        let j = m.snapshot().to_json();
        assert_eq!(j.get("knn_requests").and_then(Json::as_u64), Some(1));
        assert_eq!(j.get("retries").and_then(Json::as_u64), Some(2));
        assert_eq!(j.get("shed").and_then(Json::as_u64), Some(0));
        assert!(j.get("knn_p99_us").and_then(Json::as_f64).is_some());
        // structured output survives the wire
        let rendered = j.render();
        let back = Json::parse(&rendered).unwrap();
        assert_eq!(back.get("retries").and_then(Json::as_u64), Some(2));
    }

    #[test]
    fn snapshot_is_stable_copy() {
        let m = Metrics::new();
        m.record_knn(100);
        let s1 = m.snapshot();
        m.record_knn(100);
        assert_eq!(s1.knn_requests, 1); // unchanged copy
        assert_eq!(m.snapshot().knn_requests, 2);
    }
}
