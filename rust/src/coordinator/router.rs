//! Request router: owns the engine set and dispatches each request
//! through the resilience ladder — per-engine circuit breakers,
//! deadline-guarded attempts, retry with backoff for transient faults,
//! request-scoped deadline budgets, hedged dispatch against the next
//! healthy fallback engine, and a fallback chain that degrades
//! gracefully toward brute force.
//!
//! Engine *failures* (runtime errors, panics, deadline overruns) walk
//! the chain; *client* errors (bad k, unknown engine) are returned
//! immediately — no other engine can fix a malformed request.
//!
//! Two dispatch paths share the same attempt/breaker plumbing:
//!
//! - **sequential** (default): one engine at a time on the calling
//!   worker thread, exactly the pre-hedging behaviour;
//! - **hedged/budgeted** (when `hedge_delay` or `budget` is set):
//!   attempts run on detached threads so that after `hedge_delay`
//!   without an answer the same query is fired at the next healthy
//!   engine and the first success wins, while every retry, backoff
//!   sleep, and fallback hop draws from one per-request [`Budget`]
//!   instead of each attempt getting a fresh deadline.
//!
//! Batched queries ride the same ladder. A [`Query::Batch`] carries the
//! whole query set behind an `Arc` plus an optional dedicated
//! [`ThreadPool`]; the attempt fans contiguous chunks across the pool
//! (falling back to the engine's own `knn_batch` inline when no pool is
//! attached or the batch is trivial), and per-query failures come back
//! as [`BatchEntry::Error`] slots inside a successful batch instead of
//! failing the flight. Two entry points produce batch queries:
//!
//! - the `KNNB` protocol verb (explicit client-side batching);
//! - the **batching lane** ([`Router::attach_batch_lane`]): engine-less
//!   `KNN` requests from concurrent connections are grouped by a
//!   deadline [`Batcher`] and dispatched as one batch, with per-item
//!   budget eviction surfacing to the evicted client as a timeout and
//!   to operators via the `expired_dropped` metric.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::protocol::{BatchEntry, ErrCode, Request, Response, StatsFormat, StatsSection};
use super::resilience::{
    is_client_error, is_retryable, Budget, CircuitBreaker, ResiliencePolicy,
};
use super::worker::ThreadPool;
use crate::engine::{Neighbor, NnEngine};
use crate::error::{AsnnError, Result};
use crate::obs::{Json, QueryTrace, Recorder, Stage};
use crate::util::timer::Timer;

/// Default degradation order: most specialised engine first, exact
/// brute-force scan as the engine of last resort.
pub const DEFAULT_FALLBACK_CHAIN: [&str; 4] = ["active-pjrt", "active", "kdtree", "brute"];

/// How long a lane waiter is willing to sit on its channel when no
/// per-request budget is configured (generous: the batcher itself
/// bounds the real latency; this only guards against a lost reply).
const LANE_FALLBACK_WAIT: Duration = Duration::from_secs(30);

/// Slack added on top of budget + flush deadline before a lane waiter
/// gives up on its reply channel.
const LANE_WAIT_SLACK: Duration = Duration::from_secs(5);

/// Engine registry + dispatch policy.
pub struct Router {
    engines: HashMap<String, Arc<dyn NnEngine>>,
    breakers: HashMap<String, Arc<CircuitBreaker>>,
    fallback_chain: Vec<String>,
    policy: ResiliencePolicy,
    default_engine: String,
    metrics: Arc<Metrics>,
    /// Dedicated pool for fanning batch chunks across cores. Kept
    /// separate from the server's connection pool on purpose: a batch
    /// dispatched *from* a connection worker that queued its chunks
    /// *behind* other connections on the same pool could deadlock
    /// under load.
    batch_pool: Option<Arc<ThreadPool>>,
    batch_lane: OnceLock<BatchLane>,
    /// Telemetry hub behind `STATS2`/`TRACE`: per-stage latency
    /// histograms plus per-engine counters. Shared (via
    /// [`Router::set_recorder`]) with engines that self-report stage
    /// spans, and with the snapshotter for crash-safe export.
    obs: Arc<Recorder>,
}

/// The engine-facing part of a request. Cheap to clone — the batch
/// variant shares its query block behind an `Arc` — so it can be
/// re-sent to fallback engines and moved into attempt threads.
#[derive(Clone)]
enum Query {
    Knn { k: usize, x: f64, y: f64 },
    Classify { k: usize, x: f64, y: f64 },
    Batch { k: usize, queries: Arc<Vec<[f64; 2]>>, pool: Option<Arc<ThreadPool>> },
}

enum Outcome {
    Hits(Vec<Neighbor>),
    Label(u16),
    Batch(Vec<BatchEntry>),
}

/// One engine-less KNN waiting in the batching lane: its query plus
/// the channel its connection worker is blocked on.
struct LaneItem {
    k: usize,
    x: f64,
    y: f64,
    tx: Sender<Response>,
    /// Started at submit; its elapsed time at flush is the query's
    /// `batch_wait` stage span.
    enqueued: Timer,
}

/// The wired-in batching lane: the deadline batcher that groups
/// engine-less KNN requests, plus how long a waiter should trust its
/// reply channel before declaring the query lost.
struct BatchLane {
    batcher: Batcher<LaneItem>,
    wait: Duration,
}

/// What an attempt thread reports back: which chain slot it ran,
/// whether it was launched as a hedge, and how it went.
type AttemptReport = (usize, bool, Result<Outcome>);

fn run_query(engine: &Arc<dyn NnEngine>, q: &Query) -> Result<Outcome> {
    match q {
        Query::Knn { k, x, y } => engine.knn(&[*x, *y], *k).map(Outcome::Hits),
        Query::Classify { k, x, y } => engine.classify(&[*x, *y], *k).map(Outcome::Label),
        Query::Batch { k, queries, pool } => {
            Ok(Outcome::Batch(run_batch(engine, *k, queries, pool.as_ref())))
        }
    }
}

/// Run a whole batch on one engine. Infallible by design: per-query
/// failures (bad input, a lost pool worker) are reported in their own
/// [`BatchEntry`] slot so one poisoned query cannot sink its
/// batch-mates. (A panic on the *inline* path still unwinds into
/// `guarded`, where the normal isolation + fallback machinery takes
/// over for the whole flight.)
fn run_batch(
    engine: &Arc<dyn NnEngine>,
    k: usize,
    queries: &Arc<Vec<[f64; 2]>>,
    pool: Option<&Arc<ThreadPool>>,
) -> Vec<BatchEntry> {
    let slots: Vec<Option<Result<Vec<Neighbor>>>> = match pool {
        Some(pool) if queries.len() > 1 && pool.threads() > 1 => {
            fan_batch(engine, k, queries, pool)
        }
        _ => {
            let views: Vec<&[f64]> = queries.iter().map(|q| q.as_slice()).collect();
            engine.knn_batch(&views, k).into_iter().map(Some).collect()
        }
    };
    slots
        .into_iter()
        .map(|slot| match slot {
            Some(Ok(hits)) => BatchEntry::Hits(hits),
            Some(Err(e)) => {
                BatchEntry::Error { code: ErrCode::from(&e), message: e.to_string() }
            }
            None => BatchEntry::Error {
                code: ErrCode::Runtime,
                message: "batch worker lost (panic or pool shutdown)".into(),
            },
        })
        .collect()
}

/// Fan one batch across the dedicated pool in contiguous chunks and
/// reassemble results by offset. Degrades instead of failing:
///
/// - `execute` refused (pool shutting down) → the chunk runs inline on
///   the calling thread, so no query is dropped;
/// - a chunk job panics → the pool catches it, the job's sender drops
///   during unwind, and the missing slots stay `None` for the caller
///   to surface as per-query errors.
fn fan_batch(
    engine: &Arc<dyn NnEngine>,
    k: usize,
    queries: &Arc<Vec<[f64; 2]>>,
    pool: &Arc<ThreadPool>,
) -> Vec<Option<Result<Vec<Neighbor>>>> {
    let n = queries.len();
    let chunk = n.div_ceil(pool.threads());
    let (tx, rx) = channel::<(usize, Vec<Result<Vec<Neighbor>>>)>();
    let mut start = 0;
    while start < n {
        let end = (start + chunk).min(n);
        let job_engine = Arc::clone(engine);
        let job_queries = Arc::clone(queries);
        let job_tx = tx.clone();
        let submitted = pool.execute(move || {
            let views: Vec<&[f64]> =
                job_queries[start..end].iter().map(|q| q.as_slice()).collect();
            let _ = job_tx.send((start, job_engine.knn_batch(&views, k)));
        });
        if submitted.is_err() {
            let views: Vec<&[f64]> = queries[start..end].iter().map(|q| q.as_slice()).collect();
            let _ = tx.send((start, engine.knn_batch(&views, k)));
        }
        start = end;
    }
    drop(tx); // rx drains until every surviving job has reported
    let mut slots: Vec<Option<Result<Vec<Neighbor>>>> = (0..n).map(|_| None).collect();
    for (offset, results) in rx {
        for (i, r) in results.into_iter().enumerate() {
            if let Some(slot) = slots.get_mut(offset + i) {
                *slot = Some(r);
            }
        }
    }
    slots
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".into()
    }
}

/// One engine call, guarded: panics are caught and surfaced as runtime
/// errors; with a deadline set, the call runs on a helper thread and is
/// abandoned (thread detaches, result discarded) if it overruns.
///
/// Panics are counted *where they happen* — the helper thread records
/// its own panic before reporting, so a panic that lands after
/// `recv_timeout` has already expired is still counted exactly once
/// instead of vanishing with the abandoned thread.
fn guarded(
    engine: &Arc<dyn NnEngine>,
    q: &Query,
    deadline: Option<Duration>,
    metrics: &Arc<Metrics>,
) -> Result<Outcome> {
    match deadline {
        None => catch_unwind(AssertUnwindSafe(|| run_query(engine, q)))
            .unwrap_or_else(|p| {
                metrics.record_panic();
                Err(AsnnError::Runtime(format!("engine panicked: {}", panic_message(p))))
            }),
        Some(deadline) => {
            let (tx, rx) = channel();
            let engine = Arc::clone(engine);
            let q = q.clone();
            let thread_metrics = Arc::clone(metrics);
            std::thread::Builder::new()
                .name("asnn-deadline".into())
                .spawn(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| run_query(&engine, &q)))
                        .unwrap_or_else(|p| {
                            thread_metrics.record_panic();
                            Err(AsnnError::Runtime(format!(
                                "engine panicked: {}",
                                panic_message(p)
                            )))
                        });
                    let _ = tx.send(r);
                })
                .map_err(|e| AsnnError::Coordinator(format!("spawn deadline thread: {e}")))?;
            match rx.recv_timeout(deadline) {
                Ok(r) => r,
                Err(_) => {
                    metrics.record_timeout();
                    Err(AsnnError::Timeout(format!(
                        "engine exceeded {}ms deadline",
                        deadline.as_millis()
                    )))
                }
            }
        }
    }
}

/// Guarded attempt plus retry-with-backoff for transient failures, all
/// drawing from the request's shared budget: per-attempt deadlines are
/// clamped to the remaining budget and backoff sleeps never overrun it.
fn run_attempt(
    engine: &Arc<dyn NnEngine>,
    q: &Query,
    policy: &ResiliencePolicy,
    budget: Budget,
    metrics: &Arc<Metrics>,
    obs: &Recorder,
) -> Result<Outcome> {
    let mut attempt = 0;
    loop {
        let deadline = budget.clamp(policy.deadline);
        match guarded(engine, q, deadline, metrics) {
            Ok(out) => return Ok(out),
            Err(e)
                if is_retryable(&e)
                    && attempt < policy.retry.max_retries
                    && !budget.expired() =>
            {
                metrics.record_retry();
                let backoff = policy.retry.backoff_for(attempt);
                let slept = budget.clamp(Some(backoff)).unwrap_or(backoff);
                std::thread::sleep(slept);
                // the retry stage span is the backoff wait: added
                // latency the client paid because the attempt failed
                obs.record_stage(Stage::Retry, slept.as_nanos() as u64);
                if budget.expired() {
                    return Err(e);
                }
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Run one engine's full attempt (with retries) and settle its breaker:
/// successes close or credit it, failures feed it (counting trips), and
/// client errors leave it untouched. Runs on the dispatching worker
/// thread in the sequential path and on a detached thread when hedging,
/// so a hedged loser that eventually fails still trains its breaker.
fn settle_attempt(
    engine: &Arc<dyn NnEngine>,
    breaker: &Arc<CircuitBreaker>,
    q: &Query,
    policy: &ResiliencePolicy,
    budget: Budget,
    metrics: &Arc<Metrics>,
    obs: &Recorder,
) -> Result<Outcome> {
    // per-engine bookkeeping keys on the engine's own identity card,
    // not on whatever registry alias the request used
    let name = engine.info().name;
    let t = Timer::new();
    let res = run_attempt(engine, q, policy, budget, metrics, obs);
    match &res {
        Ok(out) => {
            breaker.record_success();
            obs.record_engine_ok(name, t.elapsed_ns());
            if let Outcome::Batch(entries) = out {
                obs.record_engine_batch(name, entries.len() as u64);
            }
        }
        Err(e) if is_client_error(e) => {
            obs.record_engine_err(name);
        }
        Err(_) => {
            obs.record_engine_err(name);
            if breaker.record_failure() {
                metrics.record_trip();
            }
        }
    }
    res
}

fn budget_exhausted_error(budget: Budget, last_err: Option<AsnnError>) -> AsnnError {
    let total_ms = budget.total().map(|d| d.as_millis()).unwrap_or(0);
    match last_err {
        Some(e) => AsnnError::Timeout(format!(
            "request budget {total_ms}ms exhausted (last error: {e})"
        )),
        None => AsnnError::Timeout(format!("request budget {total_ms}ms exhausted")),
    }
}

impl Router {
    pub fn new(default_engine: impl Into<String>, metrics: Arc<Metrics>) -> Self {
        Self::with_policy(default_engine, metrics, ResiliencePolicy::default())
    }

    pub fn with_policy(
        default_engine: impl Into<String>,
        metrics: Arc<Metrics>,
        policy: ResiliencePolicy,
    ) -> Self {
        Self {
            engines: HashMap::new(),
            breakers: HashMap::new(),
            fallback_chain: DEFAULT_FALLBACK_CHAIN.iter().map(|s| s.to_string()).collect(),
            policy,
            default_engine: default_engine.into(),
            metrics,
            batch_pool: None,
            batch_lane: OnceLock::new(),
            obs: Arc::new(Recorder::new()),
        }
    }

    /// Register `engine` under its own [`crate::engine::EngineInfo`]
    /// name — the normal path, so breaker and fallback bookkeeping key
    /// on the engine's identity card rather than a caller-chosen string.
    pub fn register_engine(&mut self, engine: Arc<dyn NnEngine>) {
        self.register(engine.info().name, engine);
    }

    /// Register `engine` under an explicit alias (tests and wrappers;
    /// prefer [`register_engine`](Self::register_engine)).
    pub fn register(&mut self, name: impl Into<String>, engine: Arc<dyn NnEngine>) {
        let name = name.into();
        self.breakers
            .insert(name.clone(), Arc::new(CircuitBreaker::new(self.policy.breaker)));
        self.engines.insert(name, engine);
    }

    /// Override the default degradation order (names absent from the
    /// registry are skipped at dispatch time).
    pub fn set_fallback_chain(&mut self, chain: Vec<String>) {
        self.fallback_chain = chain;
    }

    /// Attach the pool that batched queries fan across. Must be a
    /// *dedicated* pool (see the field docs for the deadlock rationale).
    pub fn set_batch_pool(&mut self, pool: Arc<ThreadPool>) {
        self.batch_pool = Some(pool);
    }

    /// Wire the batching lane in: engine-less `KNN` requests are held
    /// up to `deadline` to be grouped (at most `batch_max` per flush)
    /// and dispatched as one batch through the full resilience ladder.
    /// With a `budget`, items whose requester has already waited longer
    /// than it at flush time are evicted instead of processed — the
    /// waiter gets a timeout error and the eviction shows up in the
    /// `expired_dropped` metric on the next STATS.
    ///
    /// Idempotent after the first call. Takes `&Arc<Self>` because the
    /// batcher's flush thread needs a (weak) handle back to the router.
    pub fn attach_batch_lane(
        self: &Arc<Self>,
        batch_max: usize,
        deadline: Duration,
        budget: Option<Duration>,
    ) {
        let weak = Arc::downgrade(self);
        let process = move |items: Vec<LaneItem>| {
            if let Some(router) = weak.upgrade() {
                router.flush_lane(items);
            }
            // router gone (shutdown): dropping the items drops their
            // reply senders, waking every waiter with Disconnected
        };
        let batcher = match budget {
            Some(b) => Batcher::with_budget(batch_max, deadline, b, process),
            None => Batcher::new(batch_max, deadline, process),
        };
        let wait = budget.unwrap_or(LANE_FALLBACK_WAIT) + deadline + LANE_WAIT_SLACK;
        let _ = self.batch_lane.set(BatchLane { batcher, wait });
    }

    pub fn policy(&self) -> &ResiliencePolicy {
        &self.policy
    }

    pub fn engine_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.engines.keys().cloned().collect();
        v.sort();
        v
    }

    /// Breaker state per engine, sorted by name (for HEALTH probes).
    pub fn breaker_states(&self) -> Vec<(String, &'static str)> {
        let mut v: Vec<(String, &'static str)> =
            self.breakers.iter().map(|(n, b)| (n.clone(), b.state_name())).collect();
        v.sort();
        v
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// The telemetry recorder behind `STATS2`/`TRACE`.
    pub fn recorder(&self) -> &Arc<Recorder> {
        &self.obs
    }

    /// Replace the recorder (before serving): lets `main` share one
    /// recorder between the router, stage-reporting engines, and the
    /// snapshotter's persisted `obs` generations.
    pub fn set_recorder(&mut self, obs: Arc<Recorder>) {
        self.obs = obs;
    }

    /// Handle one request, recording metrics. Never panics; protocol
    /// and engine failures map to `Response::Error`.
    pub fn handle(&self, req: &Request) -> Response {
        match req {
            Request::Knn { k, x, y, engine } => match engine {
                // explicit engine choice bypasses the lane: the lane
                // batches onto the default chain only
                Some(name) => self.dispatch(Query::Knn { k: *k, x: *x, y: *y }, Some(name)),
                None => match self.try_lane(*k, *x, *y) {
                    Some(resp) => resp,
                    None => self.dispatch(Query::Knn { k: *k, x: *x, y: *y }, None),
                },
            },
            Request::Classify { k, x, y, engine } => {
                self.dispatch(Query::Classify { k: *k, x: *x, y: *y }, engine.as_deref())
            }
            Request::Knnb { k, queries, engine } => {
                self.metrics.record_batch(queries.len());
                let q = Query::Batch {
                    k: *k,
                    queries: Arc::new(queries.clone()),
                    pool: self.batch_pool.clone(),
                };
                self.dispatch(q, engine.as_deref())
            }
            Request::Stats => {
                // the batcher owns the authoritative eviction count;
                // sync it into the snapshot before rendering
                if let Some(lane) = self.batch_lane.get() {
                    self.metrics.publish_expired_dropped(lane.batcher.expired_dropped());
                }
                Response::Text(self.metrics.snapshot().render())
            }
            Request::Stats2 { format, section } => self.stats2(*format, *section),
            Request::Trace { k, x, y, engine } => {
                self.trace_query(*k, *x, *y, engine.as_deref())
            }
            Request::Health => Response::Text(self.health_line()),
            Request::Ping => Response::Text("pong".into()),
            Request::Quit => Response::Text("bye".into()),
        }
    }

    /// One-line readiness report: overall status, default engine,
    /// queue depth, engine set, and per-engine breaker states. A
    /// draining server reports `status=draining` so load balancers
    /// stop sending it traffic before the listener actually closes.
    fn health_line(&self) -> String {
        let breakers: Vec<String> = self
            .breaker_states()
            .into_iter()
            .map(|(n, s)| format!("{n}:{s}"))
            .collect();
        let default_open = self
            .breakers
            .get(&self.default_engine)
            .map(|b| b.is_open())
            .unwrap_or(true);
        let status = if self.metrics.is_draining() {
            "draining"
        } else if self.metrics.is_recovering() {
            // boot-time state recovery in progress: serving is possible
            // but the warm snapshot is still being restored
            "recovering"
        } else if default_open {
            "degraded"
        } else {
            "ok"
        };
        format!(
            "status={} default={} queue_depth={} engines={} breakers={}",
            status,
            self.default_engine,
            self.metrics.inflight(),
            self.engine_names().join(","),
            breakers.join(","),
        )
    }

    /// Build the versioned `STATS2` telemetry document. Sections:
    /// `stages` (per-stage latency histograms), `engines` (per-engine
    /// counters keyed by `EngineInfo::name`), `coordinator` (the
    /// structured form of the legacy STATS counters). `section = None`
    /// returns all three.
    fn stats2(&self, format: StatsFormat, section: Option<StatsSection>) -> Response {
        if let Some(lane) = self.batch_lane.get() {
            self.metrics.publish_expired_dropped(lane.batcher.expired_dropped());
        }
        let obs = self.obs.snapshot();
        let metrics = self.metrics.snapshot();
        let include = |s: StatsSection| section.is_none_or(|sel| sel == s);
        match format {
            StatsFormat::Json => {
                let obs_doc = obs.to_json();
                let pick = |key: &str| {
                    obs_doc.get(key).cloned().unwrap_or_else(|| Json::Obj(Vec::new()))
                };
                let mut fields = vec![("v".to_string(), Json::num_u64(2))];
                if include(StatsSection::Stages) {
                    fields.push(("stages".to_string(), pick("stages")));
                }
                if include(StatsSection::Engines) {
                    fields.push(("engines".to_string(), pick("engines")));
                }
                if include(StatsSection::Coordinator) {
                    fields.push(("coordinator".to_string(), metrics.to_json()));
                }
                Response::Text(Json::Obj(fields).render())
            }
            StatsFormat::Text => {
                let flat = obs.render_text();
                let mut parts: Vec<String> = Vec::new();
                if include(StatsSection::Stages) {
                    parts.extend(
                        flat.split_whitespace()
                            .filter(|w| w.starts_with("stage."))
                            .map(String::from),
                    );
                }
                if include(StatsSection::Engines) {
                    parts.extend(
                        flat.split_whitespace()
                            .filter(|w| w.starts_with("engine."))
                            .map(String::from),
                    );
                }
                if include(StatsSection::Coordinator) {
                    parts.push(metrics.render());
                }
                Response::Text(parts.join(" "))
            }
        }
    }

    /// Run one query through `knn_trace` and return its span tree.
    ///
    /// Deliberately bypasses the resilience ladder — no retries,
    /// hedging, fallback, or deadline — so the trace describes exactly
    /// the engine asked about, not whichever engine rescue happened to
    /// pick (see `docs/OBSERVABILITY.md`).
    fn trace_query(&self, k: usize, x: f64, y: f64, engine_override: Option<&str>) -> Response {
        let requested = engine_override.unwrap_or(&self.default_engine);
        let Some(engine) = self.engines.get(requested) else {
            self.metrics.record_error();
            return Response::from_error(&AsnnError::Coordinator(format!(
                "unknown engine {requested:?} (have: {})",
                self.engine_names().join(", ")
            )));
        };
        let name = engine.info().name;
        let total = Timer::new();
        let t_engine = Timer::new();
        match engine.knn_trace(&[x, y], k) {
            Ok((hits, search)) => {
                let engine_ns = t_engine.elapsed_ns();
                self.obs.record_engine_ok(name, engine_ns);
                let trace = QueryTrace {
                    engine: name.to_string(),
                    k,
                    query: vec![x, y],
                    engine_ns,
                    total_ns: total.elapsed_ns(),
                    neighbors: hits.len(),
                    search,
                };
                Response::Text(trace.to_json().render())
            }
            Err(e) => {
                self.obs.record_engine_err(name);
                self.metrics.record_error();
                Response::from_error(&e)
            }
        }
    }

    /// Try to route an engine-less KNN through the batching lane.
    /// `None` means "no lane, or the lane is gone" — the caller falls
    /// through to direct dispatch, so a dying batcher degrades to
    /// pre-lane behaviour instead of erroring.
    ///
    /// Per-query accounting lives here (not in the batch dispatch): a
    /// lane client sent KNN and `knn_requests` keeps meaning "KNN verbs
    /// served" whether or not batching happened behind the scenes.
    fn try_lane(&self, k: usize, x: f64, y: f64) -> Option<Response> {
        let lane = self.batch_lane.get()?;
        let t = Timer::new();
        let (tx, rx) = channel();
        if !lane.batcher.submit(LaneItem { k, x, y, tx, enqueued: Timer::new() }) {
            return None;
        }
        match rx.recv_timeout(lane.wait) {
            Ok(resp) => {
                match &resp {
                    Response::Neighbors(_) => self.metrics.record_knn(t.elapsed_ns()),
                    Response::Error { .. } => self.metrics.record_error(),
                    _ => {}
                }
                Some(resp)
            }
            Err(RecvTimeoutError::Disconnected) => {
                // the batcher evicted this item: its budget expired
                // before the batch flushed, and the sender was dropped
                self.metrics.record_budget_exhausted();
                self.metrics.record_error();
                Some(Response::from_error(&AsnnError::Timeout(
                    "request budget exhausted before its batch flushed".into(),
                )))
            }
            Err(RecvTimeoutError::Timeout) => {
                self.metrics.record_error();
                Some(Response::from_error(&AsnnError::Timeout(format!(
                    "batched query unanswered after {}ms",
                    lane.wait.as_millis()
                ))))
            }
        }
    }

    /// Flush one lane batch: group by k (one engine flight per distinct
    /// k in the window), dispatch through the normal ladder, and route
    /// each entry back to its waiter. A whole-flight failure (budget
    /// gone, all circuits open) fans the same error response to every
    /// waiter in the group.
    fn flush_lane(&self, items: Vec<LaneItem>) {
        let mut groups: HashMap<usize, Vec<LaneItem>> = HashMap::new();
        for item in items {
            groups.entry(item.k).or_default().push(item);
        }
        for (k, group) in groups {
            self.metrics.record_batch(group.len());
            for item in &group {
                self.obs.record_stage(Stage::BatchWait, item.enqueued.elapsed_ns());
            }
            let queries: Arc<Vec<[f64; 2]>> =
                Arc::new(group.iter().map(|it| [it.x, it.y]).collect());
            let q = Query::Batch { k, queries, pool: self.batch_pool.clone() };
            match self.dispatch(q, None) {
                Response::Batch(entries) if entries.len() == group.len() => {
                    for (item, entry) in group.into_iter().zip(entries) {
                        let resp = match entry {
                            BatchEntry::Hits(hits) => Response::Neighbors(hits),
                            BatchEntry::Error { code, message } => {
                                Response::Error { code, message }
                            }
                        };
                        let _ = item.tx.send(resp);
                    }
                }
                other => {
                    for item in &group {
                        let _ = item.tx.send(other.clone());
                    }
                }
            }
        }
    }

    /// The engines this request may use, in order: the requested one,
    /// then (if fallback is enabled) the registered chain entries.
    fn chain_for<'a>(&'a self, requested: &'a str) -> Vec<&'a str> {
        let mut chain = vec![requested];
        if self.policy.fallback_enabled {
            for name in &self.fallback_chain {
                if name != requested && self.engines.contains_key(name) {
                    chain.push(name.as_str());
                }
            }
        }
        chain
    }

    fn dispatch(&self, q: Query, engine_override: Option<&str>) -> Response {
        let requested = engine_override.unwrap_or(&self.default_engine);
        if !self.engines.contains_key(requested) {
            self.metrics.record_error();
            return Response::from_error(&AsnnError::Coordinator(format!(
                "unknown engine {requested:?} (have: {})",
                self.engine_names().join(", ")
            )));
        }
        let t = Timer::new();
        let outcome = if self.policy.hedge_delay.is_some() || self.policy.budget.is_some() {
            self.dispatch_hedged(&q, requested)
        } else {
            self.dispatch_sequential(&q, requested)
        };
        match outcome {
            Ok(Outcome::Hits(hits)) => {
                self.metrics.record_knn(t.elapsed_ns());
                Response::Neighbors(hits)
            }
            Ok(Outcome::Label(label)) => {
                self.metrics.record_classify(t.elapsed_ns());
                Response::Label(label)
            }
            // batches are accounted where they enter (record_batch at
            // the KNNB/lane boundary, per-query knn accounting in the
            // lane): counting them here would skew the single-query
            // request counters
            Ok(Outcome::Batch(entries)) => Response::Batch(entries),
            Err(e) => {
                self.metrics.record_error();
                Response::from_error(&e)
            }
        }
    }

    /// Classic path: walk the chain one engine at a time on the calling
    /// thread. Used whenever neither hedging nor budgeting is enabled,
    /// so the default configuration pays no extra thread per request.
    fn dispatch_sequential(&self, q: &Query, requested: &str) -> Result<Outcome> {
        let budget = Budget::unlimited();
        let mut last_err: Option<AsnnError> = None;
        for name in self.chain_for(requested) {
            let breaker = &self.breakers[name];
            if !breaker.allow() {
                continue; // circuit open: skip without spending an attempt
            }
            match settle_attempt(
                &self.engines[name],
                breaker,
                q,
                &self.policy,
                budget,
                &self.metrics,
                &self.obs,
            ) {
                Ok(out) => {
                    if name != requested {
                        self.metrics.record_fallback();
                    }
                    return Ok(out);
                }
                Err(e) if is_client_error(&e) => return Err(e),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            AsnnError::Coordinator("no engine available: all circuits open".into())
        }))
    }

    /// Hedged / budgeted path: attempts run on detached threads feeding
    /// one channel; the event loop launches the next chain engine when
    /// nothing is in flight (fallback), races a hedge after
    /// `hedge_delay` without an answer, and gives up when the budget is
    /// gone. The first success wins; a losing attempt's result is
    /// discarded when it eventually lands (its breaker bookkeeping
    /// still runs on its own thread).
    fn dispatch_hedged(&self, q: &Query, requested: &str) -> Result<Outcome> {
        let budget = Budget::start(self.policy.budget);
        let chain = self.chain_for(requested);
        let (tx, rx) = channel::<AttemptReport>();
        let mut next = 0usize; // next chain slot to consider
        let mut inflight = 0usize;
        let mut last_err: Option<AsnnError> = None;
        loop {
            if inflight == 0 {
                if budget.expired() {
                    self.metrics.record_budget_exhausted();
                    return Err(budget_exhausted_error(budget, last_err));
                }
                if self.launch(&chain, &mut next, false, q, budget, &tx) {
                    inflight += 1;
                } else {
                    return Err(last_err.unwrap_or_else(|| {
                        AsnnError::Coordinator("no engine available: all circuits open".into())
                    }));
                }
            }
            // wait for the next report, but no longer than the hedge
            // delay (when another engine could take a hedge) or the
            // remaining budget
            let hedge_wait = match self.policy.hedge_delay {
                Some(d) if self.has_launchable(&chain, next) => Some(d),
                _ => None,
            };
            let wait = match (hedge_wait, budget.remaining()) {
                (Some(h), Some(r)) => Some(h.min(r)),
                (Some(h), None) => Some(h),
                (None, Some(r)) => Some(r),
                (None, None) => None,
            };
            let report = match wait {
                Some(w) => rx.recv_timeout(w),
                None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
            };
            match report {
                Ok((idx, was_hedge, Ok(out))) => {
                    if was_hedge {
                        self.metrics.record_hedge_win();
                    }
                    if chain[idx] != requested {
                        self.metrics.record_fallback();
                    }
                    return Ok(out);
                }
                Ok((_, _, Err(e))) => {
                    inflight -= 1;
                    if is_client_error(&e) {
                        return Err(e);
                    }
                    last_err = Some(e);
                    // loop: keep waiting if a hedge is still running,
                    // otherwise launch the next chain engine
                }
                Err(RecvTimeoutError::Timeout) => {
                    if budget.expired() {
                        self.metrics.record_budget_exhausted();
                        return Err(budget_exhausted_error(budget, last_err));
                    }
                    if let Some(waited) = hedge_wait {
                        if self.launch(&chain, &mut next, true, q, budget, &tx) {
                            self.metrics.record_hedge();
                            // hedge stage span: how long the request sat
                            // on a silent engine before the hedge fired
                            self.obs.record_stage(Stage::Hedge, waited.as_nanos() as u64);
                            inflight += 1;
                        }
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // unreachable while attempts are in flight (each
                    // thread owns a sender clone); fail closed anyway
                    return Err(last_err.unwrap_or_else(|| {
                        AsnnError::Coordinator("attempt channel closed".into())
                    }));
                }
            }
        }
    }

    /// Is any not-yet-tried chain entry currently admissible? Peeks
    /// breakers without consuming their probe slot.
    fn has_launchable(&self, chain: &[&str], next: usize) -> bool {
        chain[next..].iter().any(|name| self.breakers[*name].would_allow())
    }

    /// Launch the next admissible engine at or after `next` on a
    /// detached thread; returns whether an attempt actually started.
    fn launch(
        &self,
        chain: &[&str],
        next: &mut usize,
        is_hedge: bool,
        q: &Query,
        budget: Budget,
        tx: &Sender<AttemptReport>,
    ) -> bool {
        while *next < chain.len() {
            let idx = *next;
            *next += 1;
            let name = chain[idx];
            let breaker = Arc::clone(&self.breakers[name]);
            if !breaker.allow() {
                continue; // circuit open: skip without spending an attempt
            }
            let engine = Arc::clone(&self.engines[name]);
            let metrics = Arc::clone(&self.metrics);
            let obs = Arc::clone(&self.obs);
            let policy = self.policy;
            let q = q.clone();
            let tx = tx.clone();
            let spawned = std::thread::Builder::new()
                .name("asnn-attempt".into())
                .spawn(move || {
                    let res =
                        settle_attempt(&engine, &breaker, &q, &policy, budget, &metrics, &obs);
                    let _ = tx.send((idx, is_hedge, res));
                });
            if spawned.is_ok() {
                return true;
            }
            // spawn failure: skip this engine and keep walking the chain
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::resilience::{BreakerPolicy, RetryPolicy};
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::engine::active::{ActiveEngine, ActiveParams};
    use crate::engine::brute::BruteEngine;
    use crate::engine::chaos::ChaosEngine;
    use std::time::Duration;

    fn router() -> Router {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(2000, 91)));
        let mut r = Router::new("brute", Arc::new(Metrics::new()));
        r.register("brute", Arc::new(BruteEngine::new(ds.clone())));
        r.register(
            "active",
            Arc::new(ActiveEngine::new(ds, 500, ActiveParams::default()).unwrap()),
        );
        r
    }

    #[test]
    fn routes_to_default_engine() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: None }) {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 5),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().knn_requests, 1);
    }

    #[test]
    fn routes_to_override_engine() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: Some("active".into()) }) {
            Response::Neighbors(hits) => assert!(hits.len() <= 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_engine_is_protocol_error() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: Some("nope".into()) }) {
            Response::Error { code, .. } => assert_eq!(code, ErrCode::Coordinator),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().errors, 1);
    }

    #[test]
    fn classify_and_stats() {
        let r = router();
        match r.handle(&Request::Classify { k: 11, x: 0.3, y: 0.7, engine: None }) {
            Response::Label(l) => assert!(l < 3),
            other => panic!("{other:?}"),
        }
        match r.handle(&Request::Stats) {
            Response::Text(t) => assert!(t.contains("classify=1")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn engine_error_propagates_as_response() {
        let r = router();
        match r.handle(&Request::Knn { k: 0, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { code, .. } => assert_eq!(code, ErrCode::Query),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn client_errors_do_not_fall_back_or_trip() {
        // bad k through a healthy chain: query error returned as-is,
        // breakers untouched, no fallback recorded
        let r = router();
        match r.handle(&Request::Knn { k: 0, x: 0.5, y: 0.5, engine: Some("active".into()) }) {
            Response::Error { code, .. } => assert_eq!(code, ErrCode::Query),
            other => panic!("{other:?}"),
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.fallbacks, 0);
        assert_eq!(s.breaker_trips, 0);
        assert!(r.breaker_states().iter().all(|(_, s)| *s == "closed"));
    }

    #[test]
    fn failing_engine_falls_back_and_trips_breaker() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 92)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            breaker: BreakerPolicy {
                threshold: 3,
                cooldown: Duration::from_secs(60),
                ..BreakerPolicy::default()
            },
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        r.register("chaos", Arc::new(ChaosEngine::failing(Arc::clone(&brute), 7)));
        r.register("brute", brute);
        r.set_fallback_chain(vec!["brute".into()]);

        for _ in 0..5 {
            match r.handle(&Request::Knn { k: 4, x: 0.5, y: 0.5, engine: None }) {
                Response::Neighbors(hits) => assert_eq!(hits.len(), 4),
                other => panic!("{other:?}"),
            }
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.fallbacks, 5);
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.errors, 0);
        assert!(r
            .breaker_states()
            .iter()
            .any(|(n, st)| n == "chaos" && *st == "open"));
    }

    #[test]
    fn panicking_engine_is_isolated() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 93)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let mut r = Router::new("chaos", Arc::new(Metrics::new()));
        r.register("chaos", Arc::new(ChaosEngine::panicking(Arc::clone(&brute), 8)));
        r.register("brute", brute);
        r.set_fallback_chain(vec!["brute".into()]);
        match r.handle(&Request::Classify { k: 5, x: 0.4, y: 0.4, engine: None }) {
            Response::Label(l) => assert!(l < 3),
            other => panic!("{other:?}"),
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.panics, 1);
        assert_eq!(s.fallbacks, 1);
    }

    #[test]
    fn deadline_converts_slow_engine_to_timeout() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 94)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            deadline: Some(Duration::from_millis(25)),
            fallback_enabled: false,
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        r.register(
            "chaos",
            Arc::new(ChaosEngine::slow(brute, Duration::from_millis(300), 9)),
        );
        match r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { code, .. } => assert_eq!(code, ErrCode::Timeout),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().timeouts, 1);
    }

    #[test]
    fn panic_after_deadline_expiry_is_still_counted() {
        // the engine sleeps past the deadline and then panics: the
        // request sees a timeout, and the panic landing later on the
        // abandoned helper thread must still be recorded (regression
        // test for the uncounted-panic bug)
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(500, 96)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            deadline: Some(Duration::from_millis(20)),
            fallback_enabled: false,
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        let chaos = ChaosEngine::new(
            brute,
            crate::engine::chaos::ChaosConfig {
                latency_rate: 1.0,
                latency: Duration::from_millis(80),
                panic_rate: 1.0,
                seed: 11,
                ..Default::default()
            },
        );
        r.register("chaos", Arc::new(chaos));
        match r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { code, .. } => assert_eq!(code, ErrCode::Timeout),
            other => panic!("{other:?}"),
        }
        // give the abandoned helper thread time to panic and report
        let mut recorded = 0;
        for _ in 0..50 {
            recorded = r.metrics().snapshot().panics;
            if recorded == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(recorded, 1, "late panic was not counted");
    }

    #[test]
    fn transient_errors_are_retried() {
        // error_rate 0.5: with 4 retries per request, 20 requests all
        // succeed with overwhelming probability, and retries are counted
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 95)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            retry: RetryPolicy { max_retries: 4, backoff: Duration::from_micros(100) },
            fallback_enabled: false,
            breaker: BreakerPolicy {
                threshold: 1000,
                cooldown: Duration::from_secs(60),
                ..BreakerPolicy::default()
            },
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        let chaos = ChaosEngine::new(
            brute,
            crate::engine::chaos::ChaosConfig {
                error_rate: 0.5,
                seed: 10,
                ..Default::default()
            },
        );
        r.register("chaos", Arc::new(chaos));
        let mut ok = 0;
        for _ in 0..20 {
            if let Response::Neighbors(_) =
                r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None })
            {
                ok += 1;
            }
        }
        let s = r.metrics().snapshot();
        assert!(ok >= 18, "ok={ok}");
        assert!(s.retries > 0, "{s:?}");
    }

    #[test]
    fn hedge_races_slow_primary_and_fast_fallback_wins() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1500, 97)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            hedge_delay: Some(Duration::from_millis(25)),
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        r.register(
            "chaos",
            Arc::new(ChaosEngine::slow(Arc::clone(&brute), Duration::from_millis(400), 12)),
        );
        r.register("brute", brute);
        r.set_fallback_chain(vec!["brute".into()]);

        let t0 = std::time::Instant::now();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: None }) {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 5),
            other => panic!("{other:?}"),
        }
        // the hedge answered long before the 400ms primary finished
        assert!(t0.elapsed() < Duration::from_millis(300), "{:?}", t0.elapsed());
        let s = r.metrics().snapshot();
        assert_eq!(s.hedges, 1, "{s:?}");
        assert_eq!(s.hedge_wins, 1, "{s:?}");
        assert_eq!(s.fallbacks, 1, "{s:?}");
        assert_eq!(s.errors, 0, "{s:?}");
    }

    #[test]
    fn budget_bounds_slow_engine_without_per_attempt_deadline() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 98)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            budget: Some(Duration::from_millis(50)),
            fallback_enabled: false,
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        r.register(
            "chaos",
            Arc::new(ChaosEngine::slow(brute, Duration::from_millis(400), 13)),
        );
        let t0 = std::time::Instant::now();
        match r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { code, .. } => assert_eq!(code, ErrCode::Timeout),
            other => panic!("{other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_millis(250), "{:?}", t0.elapsed());
        let s = r.metrics().snapshot();
        assert_eq!(s.budget_exhausted, 1, "{s:?}");
        assert!(s.timeouts >= 1, "{s:?}");
    }

    #[test]
    fn health_line_reports_state() {
        let r = router();
        match r.handle(&Request::Health) {
            Response::Text(t) => {
                assert!(t.contains("status=ok"), "{t}");
                assert!(t.contains("default=brute"), "{t}");
                assert!(t.contains("queue_depth=0"), "{t}");
                assert!(t.contains("engines=active,brute"), "{t}");
                assert!(t.contains("active:closed"), "{t}");
                assert!(t.contains("brute:closed"), "{t}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn health_line_reports_draining() {
        let r = router();
        r.metrics().set_draining(true);
        match r.handle(&Request::Health) {
            Response::Text(t) => assert!(t.contains("status=draining"), "{t}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn health_line_reports_recovering_then_ok() {
        let r = router();
        r.metrics().set_recovering(true);
        match r.handle(&Request::Health) {
            Response::Text(t) => assert!(t.contains("status=recovering"), "{t}"),
            other => panic!("{other:?}"),
        }
        // draining outranks recovering
        r.metrics().set_draining(true);
        match r.handle(&Request::Health) {
            Response::Text(t) => assert!(t.contains("status=draining"), "{t}"),
            other => panic!("{other:?}"),
        }
        r.metrics().set_draining(false);
        r.metrics().set_recovering(false);
        match r.handle(&Request::Health) {
            Response::Text(t) => assert!(t.contains("status=ok"), "{t}"),
            other => panic!("{other:?}"),
        }
    }

    // ───────────────────────── batch dispatch ─────────────────────────

    #[test]
    fn knnb_matches_individual_knn_across_pool_chunks() {
        let mut r = router();
        // 13 queries over 4 threads: exercises uneven chunking and the
        // reassembly-by-offset path
        r.set_batch_pool(Arc::new(ThreadPool::new(4)));
        let queries: Vec<[f64; 2]> =
            (0..13).map(|i| [(0.07 * i as f64) % 1.0, (0.13 * i as f64) % 1.0]).collect();
        let entries =
            match r.handle(&Request::Knnb { k: 5, queries: queries.clone(), engine: None }) {
                Response::Batch(entries) => entries,
                other => panic!("{other:?}"),
            };
        assert_eq!(entries.len(), 13);
        for (q, entry) in queries.iter().zip(&entries) {
            let single = match r.handle(&Request::Knn { k: 5, x: q[0], y: q[1], engine: None }) {
                Response::Neighbors(hits) => hits,
                other => panic!("{other:?}"),
            };
            // brute is exact f64 on both paths: bitwise-identical
            assert_eq!(*entry, BatchEntry::Hits(single));
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.batches, 1, "{s:?}");
        assert_eq!(s.batched_queries, 13, "{s:?}");
        // only the 13 follow-up singles count as KNN verbs
        assert_eq!(s.knn_requests, 13, "{s:?}");
        assert_eq!(s.errors, 0, "{s:?}");
    }

    #[test]
    fn knnb_respects_engine_override_and_rejects_unknown() {
        let r = router();
        match r.handle(&Request::Knnb {
            k: 7,
            queries: vec![[0.2, 0.8], [0.6, 0.4]],
            engine: Some("active".into()),
        }) {
            Response::Batch(entries) => {
                assert_eq!(entries.len(), 2);
                for e in &entries {
                    assert!(matches!(e, BatchEntry::Hits(_)), "{e:?}");
                }
            }
            other => panic!("{other:?}"),
        }
        match r.handle(&Request::Knnb {
            k: 7,
            queries: vec![[0.2, 0.8]],
            engine: Some("nope".into()),
        }) {
            Response::Error { code, .. } => assert_eq!(code, ErrCode::Coordinator),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn knnb_per_query_errors_ride_inside_an_ok_batch() {
        let r = router();
        // k = 0 fails input validation per query, not per flight
        let resp = r.handle(&Request::Knnb {
            k: 0,
            queries: vec![[0.5, 0.5], [0.2, 0.2]],
            engine: None,
        });
        match resp {
            Response::Batch(entries) => {
                assert_eq!(entries.len(), 2);
                for e in &entries {
                    match e {
                        BatchEntry::Error { code, .. } => assert_eq!(*code, ErrCode::Query),
                        other => panic!("{other:?}"),
                    }
                }
            }
            other => panic!("{other:?}"),
        }
        // the flight itself succeeded: no whole-batch error recorded
        assert_eq!(r.metrics().snapshot().errors, 0);
    }

    #[test]
    fn batch_worker_loss_yields_per_entry_errors_not_a_dead_batch() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(500, 99)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let mut r = Router::new("chaos", Arc::new(Metrics::new()));
        r.register("chaos", Arc::new(ChaosEngine::panicking(brute, 14)));
        r.set_fallback_chain(vec![]);
        let pool = Arc::new(ThreadPool::new(2));
        r.set_batch_pool(Arc::clone(&pool));
        let resp = r.handle(&Request::Knnb {
            k: 3,
            queries: vec![[0.1, 0.1], [0.2, 0.2], [0.3, 0.3], [0.4, 0.4]],
            engine: None,
        });
        match resp {
            Response::Batch(entries) => {
                assert_eq!(entries.len(), 4);
                for e in entries {
                    match e {
                        BatchEntry::Error { code, message } => {
                            assert_eq!(code, ErrCode::Runtime);
                            assert!(message.contains("batch worker lost"), "{message}");
                        }
                        other => panic!("{other:?}"),
                    }
                }
            }
            other => panic!("{other:?}"),
        }
        // both chunk jobs panicked inside the pool (give the workers a
        // beat to finish their catch_unwind bookkeeping)
        let mut caught = 0;
        for _ in 0..50 {
            caught = pool.panics_caught();
            if caught == 2 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(caught, 2, "pool did not isolate the chunk panics");
    }

    #[test]
    fn inline_batch_panic_walks_the_fallback_chain() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(500, 90)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let mut r = Router::new("chaos", Arc::new(Metrics::new()));
        r.register("chaos", Arc::new(ChaosEngine::panicking(Arc::clone(&brute), 15)));
        r.register("brute", brute);
        r.set_fallback_chain(vec!["brute".into()]);
        // no batch pool: the panic surfaces through guarded() and the
        // whole batch retries on the fallback engine
        let resp = r.handle(&Request::Knnb {
            k: 4,
            queries: vec![[0.5, 0.5], [0.6, 0.4]],
            engine: None,
        });
        match resp {
            Response::Batch(entries) => {
                assert_eq!(entries.len(), 2);
                for e in entries {
                    match e {
                        BatchEntry::Hits(hits) => assert_eq!(hits.len(), 4),
                        other => panic!("{other:?}"),
                    }
                }
            }
            other => panic!("{other:?}"),
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.panics, 1, "{s:?}");
        assert_eq!(s.fallbacks, 1, "{s:?}");
    }

    // ───────────────────────── batching lane ──────────────────────────

    #[test]
    fn lane_batches_concurrent_knn_requests() {
        let mut r = router();
        r.set_batch_pool(Arc::new(ThreadPool::new(2)));
        let r = Arc::new(r);
        r.attach_batch_lane(8, Duration::from_millis(100), None);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let x = 0.1 + 0.09 * i as f64;
                    (x, r.handle(&Request::Knn { k: 5, x, y: 0.5, engine: None }))
                })
            })
            .collect();
        let answers: Vec<(f64, Response)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (x, resp) in &answers {
            let hits = match resp {
                Response::Neighbors(hits) => hits.clone(),
                other => panic!("{other:?}"),
            };
            // engine-override requests skip the lane: direct exact path
            let direct = match r.handle(&Request::Knn {
                k: 5,
                x: *x,
                y: 0.5,
                engine: Some("brute".into()),
            }) {
                Response::Neighbors(hits) => hits,
                other => panic!("{other:?}"),
            };
            assert_eq!(hits, direct);
        }
        let s = r.metrics().snapshot();
        // 8 through the lane + 8 direct comparisons
        assert_eq!(s.knn_requests, 16, "{s:?}");
        assert!(s.batches >= 1, "{s:?}");
        assert_eq!(s.batched_queries, 8, "{s:?}");
        assert_eq!(s.errors, 0, "{s:?}");
    }

    #[test]
    fn lane_evicts_budget_expired_queries_and_reports_them() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(500, 89)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let mut r = Router::new("chaos", Arc::new(Metrics::new()));
        r.register("chaos", Arc::new(ChaosEngine::slow(brute, Duration::from_millis(250), 16)));
        let r = Arc::new(r);
        r.attach_batch_lane(16, Duration::from_millis(5), Some(Duration::from_millis(50)));

        // the first query flushes alone at ~5ms and stalls the lane on
        // the 250ms engine
        let r0 = Arc::clone(&r);
        let first = std::thread::spawn(move || {
            r0.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None })
        });
        std::thread::sleep(Duration::from_millis(40));
        // these sit queued past their 50ms budget while the lane stalls
        let late: Vec<_> = (0..2)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    r.handle(&Request::Knn { k: 3, x: 0.4, y: 0.6, engine: None })
                })
            })
            .collect();
        match first.join().unwrap() {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 3),
            other => panic!("{other:?}"),
        }
        for h in late {
            match h.join().unwrap() {
                Response::Error { code, message } => {
                    assert_eq!(code, ErrCode::Timeout);
                    assert!(message.contains("budget exhausted"), "{message}");
                }
                other => panic!("{other:?}"),
            }
        }
        r.handle(&Request::Stats); // syncs expired_dropped from the batcher
        let s = r.metrics().snapshot();
        assert_eq!(s.expired_dropped, 2, "{s:?}");
        assert_eq!(s.budget_exhausted, 2, "{s:?}");
        assert_eq!(s.errors, 2, "{s:?}");
        assert_eq!(s.knn_requests, 1, "{s:?}");
        assert_eq!(s.batched_queries, 1, "{s:?}");
    }

    #[test]
    fn register_engine_keys_on_info_name() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(300, 92)));
        let mut r = Router::new("brute", Arc::new(Metrics::new()));
        r.register_engine(Arc::new(BruteEngine::new(ds)));
        assert_eq!(r.engine_names(), vec!["brute".to_string()]);
        match r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }) {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats2_json_reports_engines_and_coordinator() {
        let r = router();
        for _ in 0..3 {
            r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: None });
        }
        r.handle(&Request::Knn { k: 0, x: 0.5, y: 0.5, engine: None }); // client error
        let doc = match r.handle(&Request::Stats2 {
            format: StatsFormat::Json,
            section: None,
        }) {
            Response::Text(t) => Json::parse(&t).unwrap(),
            other => panic!("{other:?}"),
        };
        assert_eq!(doc.get("v").and_then(Json::as_u64), Some(2));
        // every stage histogram is present, even if empty
        let stages = doc.get("stages").unwrap();
        for stage in Stage::ALL {
            let h = stages.get(stage.as_str()).unwrap_or_else(|| panic!("{stage:?}"));
            assert!(h.get("p50_ns").is_some(), "{stage:?}");
        }
        // the brute default engine settled 3 ok + 1 failed attempt
        let brute = doc.get("engines").unwrap().get("brute").unwrap();
        assert_eq!(brute.get("requests").and_then(Json::as_u64), Some(4));
        assert_eq!(brute.get("errors").and_then(Json::as_u64), Some(1));
        // coordinator section mirrors the legacy counters
        let coord = doc.get("coordinator").unwrap();
        assert_eq!(coord.get("knn_requests").and_then(Json::as_u64), Some(3));
        assert_eq!(coord.get("errors").and_then(Json::as_u64), Some(1));
    }

    #[test]
    fn stats2_sections_filter_the_document() {
        let r = router();
        let engines_only = match r.handle(&Request::Stats2 {
            format: StatsFormat::Json,
            section: Some(StatsSection::Engines),
        }) {
            Response::Text(t) => Json::parse(&t).unwrap(),
            other => panic!("{other:?}"),
        };
        assert!(engines_only.get("engines").is_some());
        assert!(engines_only.get("stages").is_none());
        assert!(engines_only.get("coordinator").is_none());

        match r.handle(&Request::Stats2 {
            format: StatsFormat::Text,
            section: Some(StatsSection::Coordinator),
        }) {
            // text coordinator section is exactly the legacy STATS line
            Response::Text(t) => assert_eq!(t, r.metrics().snapshot().render()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn trace_returns_span_tree_with_bounded_durations() {
        let r = router();
        let doc = match r.handle(&Request::Trace {
            k: 7,
            x: 0.5,
            y: 0.5,
            engine: Some("active".into()),
        }) {
            Response::Text(t) => Json::parse(&t).unwrap(),
            other => panic!("{other:?}"),
        };
        assert_eq!(doc.get("engine").and_then(Json::as_str), Some("active"));
        assert_eq!(doc.get("neighbors").and_then(Json::as_u64), Some(7));
        let total_ns = doc.get("total_ns").and_then(Json::as_u64).unwrap();
        let root = doc.get("root").unwrap();
        let engine_span = &root.get("children").unwrap().as_arr().unwrap()[0];
        let engine_ns = engine_span.get("dur_ns").and_then(Json::as_u64).unwrap();
        let leaf_sum: u64 = engine_span
            .get("children")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|l| l.get("dur_ns").and_then(Json::as_u64).unwrap())
            .sum();
        assert!(leaf_sum <= engine_ns, "{leaf_sum} > {engine_ns}");
        assert!(engine_ns <= total_ns, "{engine_ns} > {total_ns}");
        // the active engine reports real per-stage spans
        let names: Vec<&str> = engine_span
            .get("children")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|l| l.get("name").and_then(Json::as_str).unwrap())
            .collect();
        assert!(names.contains(&"coarse"), "{names:?}");
        assert!(names.contains(&"scan"), "{names:?}");
    }

    #[test]
    fn trace_unknown_engine_is_coordinator_error() {
        let r = router();
        match r.handle(&Request::Trace { k: 3, x: 0.5, y: 0.5, engine: Some("nope".into()) }) {
            Response::Error { code, .. } => assert_eq!(code, ErrCode::Coordinator),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn retries_feed_the_retry_stage_histogram() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(300, 93)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            retry: RetryPolicy { max_retries: 3, backoff: Duration::from_millis(1) },
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        // flapping period 2: first two calls fail, next two succeed —
        // the retry loop crosses into the healthy window
        r.register("chaos", Arc::new(ChaosEngine::flapping(brute, 2, 94)));
        match r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }) {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 3),
            other => panic!("{other:?}"),
        }
        let snap = r.recorder().snapshot();
        assert_eq!(snap.stage(Stage::Retry).unwrap().count, 2);
        let chaos = snap.engines.iter().find(|e| e.name == "chaos").unwrap();
        assert_eq!(chaos.requests, 1); // one settled attempt, retried internally
    }
}
