//! Request router: owns the engine set and dispatches each request
//! through the resilience ladder — per-engine circuit breakers,
//! deadline-guarded attempts, retry with backoff for transient faults,
//! request-scoped deadline budgets, hedged dispatch against the next
//! healthy fallback engine, and a fallback chain that degrades
//! gracefully toward brute force.
//!
//! Engine *failures* (runtime errors, panics, deadline overruns) walk
//! the chain; *client* errors (bad k, unknown engine) are returned
//! immediately — no other engine can fix a malformed request.
//!
//! Two dispatch paths share the same attempt/breaker plumbing:
//!
//! - **sequential** (default): one engine at a time on the calling
//!   worker thread, exactly the pre-hedging behaviour;
//! - **hedged/budgeted** (when `hedge_delay` or `budget` is set):
//!   attempts run on detached threads so that after `hedge_delay`
//!   without an answer the same query is fired at the next healthy
//!   engine and the first success wins, while every retry, backoff
//!   sleep, and fallback hop draws from one per-request [`Budget`]
//!   instead of each attempt getting a fresh deadline.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use super::metrics::Metrics;
use super::protocol::{Request, Response};
use super::resilience::{
    is_client_error, is_retryable, Budget, CircuitBreaker, ResiliencePolicy,
};
use crate::engine::{Neighbor, NnEngine};
use crate::error::{AsnnError, Result};
use crate::util::timer::Timer;

/// Default degradation order: most specialised engine first, exact
/// brute-force scan as the engine of last resort.
pub const DEFAULT_FALLBACK_CHAIN: [&str; 4] = ["active-pjrt", "active", "kdtree", "brute"];

/// Engine registry + dispatch policy.
pub struct Router {
    engines: HashMap<String, Arc<dyn NnEngine>>,
    breakers: HashMap<String, Arc<CircuitBreaker>>,
    fallback_chain: Vec<String>,
    policy: ResiliencePolicy,
    default_engine: String,
    metrics: Arc<Metrics>,
}

/// The engine-facing part of a request (small and `Copy` so it can be
/// re-sent to fallback engines and moved into attempt threads).
#[derive(Debug, Clone, Copy)]
enum Query {
    Knn { k: usize, x: f64, y: f64 },
    Classify { k: usize, x: f64, y: f64 },
}

enum Outcome {
    Hits(Vec<Neighbor>),
    Label(u16),
}

/// What an attempt thread reports back: which chain slot it ran,
/// whether it was launched as a hedge, and how it went.
type AttemptReport = (usize, bool, Result<Outcome>);

fn run_query(engine: &dyn NnEngine, q: Query) -> Result<Outcome> {
    match q {
        Query::Knn { k, x, y } => engine.knn(&[x, y], k).map(Outcome::Hits),
        Query::Classify { k, x, y } => engine.classify(&[x, y], k).map(Outcome::Label),
    }
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".into()
    }
}

/// One engine call, guarded: panics are caught and surfaced as runtime
/// errors; with a deadline set, the call runs on a helper thread and is
/// abandoned (thread detaches, result discarded) if it overruns.
///
/// Panics are counted *where they happen* — the helper thread records
/// its own panic before reporting, so a panic that lands after
/// `recv_timeout` has already expired is still counted exactly once
/// instead of vanishing with the abandoned thread.
fn guarded(
    engine: &Arc<dyn NnEngine>,
    q: Query,
    deadline: Option<Duration>,
    metrics: &Arc<Metrics>,
) -> Result<Outcome> {
    match deadline {
        None => catch_unwind(AssertUnwindSafe(|| run_query(engine.as_ref(), q)))
            .unwrap_or_else(|p| {
                metrics.record_panic();
                Err(AsnnError::Runtime(format!("engine panicked: {}", panic_message(p))))
            }),
        Some(deadline) => {
            let (tx, rx) = channel();
            let engine = Arc::clone(engine);
            let thread_metrics = Arc::clone(metrics);
            std::thread::Builder::new()
                .name("asnn-deadline".into())
                .spawn(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| run_query(engine.as_ref(), q)))
                        .unwrap_or_else(|p| {
                            thread_metrics.record_panic();
                            Err(AsnnError::Runtime(format!(
                                "engine panicked: {}",
                                panic_message(p)
                            )))
                        });
                    let _ = tx.send(r);
                })
                .map_err(|e| AsnnError::Coordinator(format!("spawn deadline thread: {e}")))?;
            match rx.recv_timeout(deadline) {
                Ok(r) => r,
                Err(_) => {
                    metrics.record_timeout();
                    Err(AsnnError::Timeout(format!(
                        "engine exceeded {}ms deadline",
                        deadline.as_millis()
                    )))
                }
            }
        }
    }
}

/// Guarded attempt plus retry-with-backoff for transient failures, all
/// drawing from the request's shared budget: per-attempt deadlines are
/// clamped to the remaining budget and backoff sleeps never overrun it.
fn run_attempt(
    engine: &Arc<dyn NnEngine>,
    q: Query,
    policy: &ResiliencePolicy,
    budget: Budget,
    metrics: &Arc<Metrics>,
) -> Result<Outcome> {
    let mut attempt = 0;
    loop {
        let deadline = budget.clamp(policy.deadline);
        match guarded(engine, q, deadline, metrics) {
            Ok(out) => return Ok(out),
            Err(e)
                if is_retryable(&e)
                    && attempt < policy.retry.max_retries
                    && !budget.expired() =>
            {
                metrics.record_retry();
                let backoff = policy.retry.backoff_for(attempt);
                std::thread::sleep(budget.clamp(Some(backoff)).unwrap_or(backoff));
                if budget.expired() {
                    return Err(e);
                }
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Run one engine's full attempt (with retries) and settle its breaker:
/// successes close or credit it, failures feed it (counting trips), and
/// client errors leave it untouched. Runs on the dispatching worker
/// thread in the sequential path and on a detached thread when hedging,
/// so a hedged loser that eventually fails still trains its breaker.
fn settle_attempt(
    engine: &Arc<dyn NnEngine>,
    breaker: &Arc<CircuitBreaker>,
    q: Query,
    policy: &ResiliencePolicy,
    budget: Budget,
    metrics: &Arc<Metrics>,
) -> Result<Outcome> {
    let res = run_attempt(engine, q, policy, budget, metrics);
    match &res {
        Ok(_) => breaker.record_success(),
        Err(e) if is_client_error(e) => {}
        Err(_) => {
            if breaker.record_failure() {
                metrics.record_trip();
            }
        }
    }
    res
}

fn budget_exhausted_error(budget: Budget, last_err: Option<AsnnError>) -> AsnnError {
    let total_ms = budget.total().map(|d| d.as_millis()).unwrap_or(0);
    match last_err {
        Some(e) => AsnnError::Timeout(format!(
            "request budget {total_ms}ms exhausted (last error: {e})"
        )),
        None => AsnnError::Timeout(format!("request budget {total_ms}ms exhausted")),
    }
}

impl Router {
    pub fn new(default_engine: impl Into<String>, metrics: Arc<Metrics>) -> Self {
        Self::with_policy(default_engine, metrics, ResiliencePolicy::default())
    }

    pub fn with_policy(
        default_engine: impl Into<String>,
        metrics: Arc<Metrics>,
        policy: ResiliencePolicy,
    ) -> Self {
        Self {
            engines: HashMap::new(),
            breakers: HashMap::new(),
            fallback_chain: DEFAULT_FALLBACK_CHAIN.iter().map(|s| s.to_string()).collect(),
            policy,
            default_engine: default_engine.into(),
            metrics,
        }
    }

    pub fn register(&mut self, name: impl Into<String>, engine: Arc<dyn NnEngine>) {
        let name = name.into();
        self.breakers
            .insert(name.clone(), Arc::new(CircuitBreaker::new(self.policy.breaker)));
        self.engines.insert(name, engine);
    }

    /// Override the default degradation order (names absent from the
    /// registry are skipped at dispatch time).
    pub fn set_fallback_chain(&mut self, chain: Vec<String>) {
        self.fallback_chain = chain;
    }

    pub fn policy(&self) -> &ResiliencePolicy {
        &self.policy
    }

    pub fn engine_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.engines.keys().cloned().collect();
        v.sort();
        v
    }

    /// Breaker state per engine, sorted by name (for HEALTH probes).
    pub fn breaker_states(&self) -> Vec<(String, &'static str)> {
        let mut v: Vec<(String, &'static str)> =
            self.breakers.iter().map(|(n, b)| (n.clone(), b.state_name())).collect();
        v.sort();
        v
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Handle one request, recording metrics. Never panics; protocol
    /// and engine failures map to `Response::Error`.
    pub fn handle(&self, req: &Request) -> Response {
        match req {
            Request::Knn { k, x, y, engine } => {
                self.dispatch(Query::Knn { k: *k, x: *x, y: *y }, engine.as_deref())
            }
            Request::Classify { k, x, y, engine } => {
                self.dispatch(Query::Classify { k: *k, x: *x, y: *y }, engine.as_deref())
            }
            Request::Stats => Response::Text(self.metrics.snapshot().render()),
            Request::Health => Response::Text(self.health_line()),
            Request::Ping => Response::Text("pong".into()),
            Request::Quit => Response::Text("bye".into()),
        }
    }

    /// One-line readiness report: overall status, default engine,
    /// queue depth, engine set, and per-engine breaker states. A
    /// draining server reports `status=draining` so load balancers
    /// stop sending it traffic before the listener actually closes.
    fn health_line(&self) -> String {
        let breakers: Vec<String> = self
            .breaker_states()
            .into_iter()
            .map(|(n, s)| format!("{n}:{s}"))
            .collect();
        let default_open = self
            .breakers
            .get(&self.default_engine)
            .map(|b| b.is_open())
            .unwrap_or(true);
        let status = if self.metrics.is_draining() {
            "draining"
        } else if self.metrics.is_recovering() {
            // boot-time state recovery in progress: serving is possible
            // but the warm snapshot is still being restored
            "recovering"
        } else if default_open {
            "degraded"
        } else {
            "ok"
        };
        format!(
            "status={} default={} queue_depth={} engines={} breakers={}",
            status,
            self.default_engine,
            self.metrics.inflight(),
            self.engine_names().join(","),
            breakers.join(","),
        )
    }

    /// The engines this request may use, in order: the requested one,
    /// then (if fallback is enabled) the registered chain entries.
    fn chain_for<'a>(&'a self, requested: &'a str) -> Vec<&'a str> {
        let mut chain = vec![requested];
        if self.policy.fallback_enabled {
            for name in &self.fallback_chain {
                if name != requested && self.engines.contains_key(name) {
                    chain.push(name.as_str());
                }
            }
        }
        chain
    }

    fn dispatch(&self, q: Query, engine_override: Option<&str>) -> Response {
        let requested = engine_override.unwrap_or(&self.default_engine);
        if !self.engines.contains_key(requested) {
            self.metrics.record_error();
            return Response::from_error(&AsnnError::Coordinator(format!(
                "unknown engine {requested:?} (have: {})",
                self.engine_names().join(", ")
            )));
        }
        let t = Timer::new();
        let outcome = if self.policy.hedge_delay.is_some() || self.policy.budget.is_some() {
            self.dispatch_hedged(q, requested)
        } else {
            self.dispatch_sequential(q, requested)
        };
        match outcome {
            Ok(Outcome::Hits(hits)) => {
                self.metrics.record_knn(t.elapsed_ns());
                Response::Neighbors(hits)
            }
            Ok(Outcome::Label(label)) => {
                self.metrics.record_classify(t.elapsed_ns());
                Response::Label(label)
            }
            Err(e) => {
                self.metrics.record_error();
                Response::from_error(&e)
            }
        }
    }

    /// Classic path: walk the chain one engine at a time on the calling
    /// thread. Used whenever neither hedging nor budgeting is enabled,
    /// so the default configuration pays no extra thread per request.
    fn dispatch_sequential(&self, q: Query, requested: &str) -> Result<Outcome> {
        let budget = Budget::unlimited();
        let mut last_err: Option<AsnnError> = None;
        for name in self.chain_for(requested) {
            let breaker = &self.breakers[name];
            if !breaker.allow() {
                continue; // circuit open: skip without spending an attempt
            }
            match settle_attempt(&self.engines[name], breaker, q, &self.policy, budget, &self.metrics)
            {
                Ok(out) => {
                    if name != requested {
                        self.metrics.record_fallback();
                    }
                    return Ok(out);
                }
                Err(e) if is_client_error(&e) => return Err(e),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            AsnnError::Coordinator("no engine available: all circuits open".into())
        }))
    }

    /// Hedged / budgeted path: attempts run on detached threads feeding
    /// one channel; the event loop launches the next chain engine when
    /// nothing is in flight (fallback), races a hedge after
    /// `hedge_delay` without an answer, and gives up when the budget is
    /// gone. The first success wins; a losing attempt's result is
    /// discarded when it eventually lands (its breaker bookkeeping
    /// still runs on its own thread).
    fn dispatch_hedged(&self, q: Query, requested: &str) -> Result<Outcome> {
        let budget = Budget::start(self.policy.budget);
        let chain = self.chain_for(requested);
        let (tx, rx) = channel::<AttemptReport>();
        let mut next = 0usize; // next chain slot to consider
        let mut inflight = 0usize;
        let mut last_err: Option<AsnnError> = None;
        loop {
            if inflight == 0 {
                if budget.expired() {
                    self.metrics.record_budget_exhausted();
                    return Err(budget_exhausted_error(budget, last_err));
                }
                if self.launch(&chain, &mut next, false, q, budget, &tx) {
                    inflight += 1;
                } else {
                    return Err(last_err.unwrap_or_else(|| {
                        AsnnError::Coordinator("no engine available: all circuits open".into())
                    }));
                }
            }
            // wait for the next report, but no longer than the hedge
            // delay (when another engine could take a hedge) or the
            // remaining budget
            let hedge_wait = match self.policy.hedge_delay {
                Some(d) if self.has_launchable(&chain, next) => Some(d),
                _ => None,
            };
            let wait = match (hedge_wait, budget.remaining()) {
                (Some(h), Some(r)) => Some(h.min(r)),
                (Some(h), None) => Some(h),
                (None, Some(r)) => Some(r),
                (None, None) => None,
            };
            let report = match wait {
                Some(w) => rx.recv_timeout(w),
                None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
            };
            match report {
                Ok((idx, was_hedge, Ok(out))) => {
                    if was_hedge {
                        self.metrics.record_hedge_win();
                    }
                    if chain[idx] != requested {
                        self.metrics.record_fallback();
                    }
                    return Ok(out);
                }
                Ok((_, _, Err(e))) => {
                    inflight -= 1;
                    if is_client_error(&e) {
                        return Err(e);
                    }
                    last_err = Some(e);
                    // loop: keep waiting if a hedge is still running,
                    // otherwise launch the next chain engine
                }
                Err(RecvTimeoutError::Timeout) => {
                    if budget.expired() {
                        self.metrics.record_budget_exhausted();
                        return Err(budget_exhausted_error(budget, last_err));
                    }
                    if hedge_wait.is_some()
                        && self.launch(&chain, &mut next, true, q, budget, &tx)
                    {
                        self.metrics.record_hedge();
                        inflight += 1;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // unreachable while attempts are in flight (each
                    // thread owns a sender clone); fail closed anyway
                    return Err(last_err.unwrap_or_else(|| {
                        AsnnError::Coordinator("attempt channel closed".into())
                    }));
                }
            }
        }
    }

    /// Is any not-yet-tried chain entry currently admissible? Peeks
    /// breakers without consuming their probe slot.
    fn has_launchable(&self, chain: &[&str], next: usize) -> bool {
        chain[next..].iter().any(|name| self.breakers[*name].would_allow())
    }

    /// Launch the next admissible engine at or after `next` on a
    /// detached thread; returns whether an attempt actually started.
    fn launch(
        &self,
        chain: &[&str],
        next: &mut usize,
        is_hedge: bool,
        q: Query,
        budget: Budget,
        tx: &Sender<AttemptReport>,
    ) -> bool {
        while *next < chain.len() {
            let idx = *next;
            *next += 1;
            let name = chain[idx];
            let breaker = Arc::clone(&self.breakers[name]);
            if !breaker.allow() {
                continue; // circuit open: skip without spending an attempt
            }
            let engine = Arc::clone(&self.engines[name]);
            let metrics = Arc::clone(&self.metrics);
            let policy = self.policy;
            let tx = tx.clone();
            let spawned = std::thread::Builder::new()
                .name("asnn-attempt".into())
                .spawn(move || {
                    let res = settle_attempt(&engine, &breaker, q, &policy, budget, &metrics);
                    let _ = tx.send((idx, is_hedge, res));
                });
            if spawned.is_ok() {
                return true;
            }
            // spawn failure: skip this engine and keep walking the chain
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::resilience::{BreakerPolicy, RetryPolicy};
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::engine::active::{ActiveEngine, ActiveParams};
    use crate::engine::brute::BruteEngine;
    use crate::engine::chaos::ChaosEngine;
    use std::time::Duration;

    fn router() -> Router {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(2000, 91)));
        let mut r = Router::new("brute", Arc::new(Metrics::new()));
        r.register("brute", Arc::new(BruteEngine::new(ds.clone())));
        r.register(
            "active",
            Arc::new(ActiveEngine::new(ds, 500, ActiveParams::default()).unwrap()),
        );
        r
    }

    #[test]
    fn routes_to_default_engine() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: None }) {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 5),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().knn_requests, 1);
    }

    #[test]
    fn routes_to_override_engine() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: Some("active".into()) }) {
            Response::Neighbors(hits) => assert!(hits.len() <= 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_engine_is_protocol_error() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: Some("nope".into()) }) {
            Response::Error { domain, .. } => assert_eq!(domain, "coordinator"),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().errors, 1);
    }

    #[test]
    fn classify_and_stats() {
        let r = router();
        match r.handle(&Request::Classify { k: 11, x: 0.3, y: 0.7, engine: None }) {
            Response::Label(l) => assert!(l < 3),
            other => panic!("{other:?}"),
        }
        match r.handle(&Request::Stats) {
            Response::Text(t) => assert!(t.contains("classify=1")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn engine_error_propagates_as_response() {
        let r = router();
        match r.handle(&Request::Knn { k: 0, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { domain, .. } => assert_eq!(domain, "query"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn client_errors_do_not_fall_back_or_trip() {
        // bad k through a healthy chain: query error returned as-is,
        // breakers untouched, no fallback recorded
        let r = router();
        match r.handle(&Request::Knn { k: 0, x: 0.5, y: 0.5, engine: Some("active".into()) }) {
            Response::Error { domain, .. } => assert_eq!(domain, "query"),
            other => panic!("{other:?}"),
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.fallbacks, 0);
        assert_eq!(s.breaker_trips, 0);
        assert!(r.breaker_states().iter().all(|(_, s)| *s == "closed"));
    }

    #[test]
    fn failing_engine_falls_back_and_trips_breaker() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 92)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            breaker: BreakerPolicy {
                threshold: 3,
                cooldown: Duration::from_secs(60),
                ..BreakerPolicy::default()
            },
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        r.register("chaos", Arc::new(ChaosEngine::failing(Arc::clone(&brute), 7)));
        r.register("brute", brute);
        r.set_fallback_chain(vec!["brute".into()]);

        for _ in 0..5 {
            match r.handle(&Request::Knn { k: 4, x: 0.5, y: 0.5, engine: None }) {
                Response::Neighbors(hits) => assert_eq!(hits.len(), 4),
                other => panic!("{other:?}"),
            }
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.fallbacks, 5);
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.errors, 0);
        assert!(r
            .breaker_states()
            .iter()
            .any(|(n, st)| n == "chaos" && *st == "open"));
    }

    #[test]
    fn panicking_engine_is_isolated() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 93)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let mut r = Router::new("chaos", Arc::new(Metrics::new()));
        r.register("chaos", Arc::new(ChaosEngine::panicking(Arc::clone(&brute), 8)));
        r.register("brute", brute);
        r.set_fallback_chain(vec!["brute".into()]);
        match r.handle(&Request::Classify { k: 5, x: 0.4, y: 0.4, engine: None }) {
            Response::Label(l) => assert!(l < 3),
            other => panic!("{other:?}"),
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.panics, 1);
        assert_eq!(s.fallbacks, 1);
    }

    #[test]
    fn deadline_converts_slow_engine_to_timeout() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 94)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            deadline: Some(Duration::from_millis(25)),
            fallback_enabled: false,
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        r.register(
            "chaos",
            Arc::new(ChaosEngine::slow(brute, Duration::from_millis(300), 9)),
        );
        match r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { domain, .. } => assert_eq!(domain, "timeout"),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().timeouts, 1);
    }

    #[test]
    fn panic_after_deadline_expiry_is_still_counted() {
        // the engine sleeps past the deadline and then panics: the
        // request sees a timeout, and the panic landing later on the
        // abandoned helper thread must still be recorded (regression
        // test for the uncounted-panic bug)
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(500, 96)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            deadline: Some(Duration::from_millis(20)),
            fallback_enabled: false,
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        let chaos = ChaosEngine::new(
            brute,
            crate::engine::chaos::ChaosConfig {
                latency_rate: 1.0,
                latency: Duration::from_millis(80),
                panic_rate: 1.0,
                seed: 11,
                ..Default::default()
            },
        );
        r.register("chaos", Arc::new(chaos));
        match r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { domain, .. } => assert_eq!(domain, "timeout"),
            other => panic!("{other:?}"),
        }
        // give the abandoned helper thread time to panic and report
        let mut recorded = 0;
        for _ in 0..50 {
            recorded = r.metrics().snapshot().panics;
            if recorded == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(recorded, 1, "late panic was not counted");
    }

    #[test]
    fn transient_errors_are_retried() {
        // error_rate 0.5: with 4 retries per request, 20 requests all
        // succeed with overwhelming probability, and retries are counted
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 95)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            retry: RetryPolicy { max_retries: 4, backoff: Duration::from_micros(100) },
            fallback_enabled: false,
            breaker: BreakerPolicy {
                threshold: 1000,
                cooldown: Duration::from_secs(60),
                ..BreakerPolicy::default()
            },
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        let chaos = ChaosEngine::new(
            brute,
            crate::engine::chaos::ChaosConfig {
                error_rate: 0.5,
                seed: 10,
                ..Default::default()
            },
        );
        r.register("chaos", Arc::new(chaos));
        let mut ok = 0;
        for _ in 0..20 {
            if let Response::Neighbors(_) =
                r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None })
            {
                ok += 1;
            }
        }
        let s = r.metrics().snapshot();
        assert!(ok >= 18, "ok={ok}");
        assert!(s.retries > 0, "{s:?}");
    }

    #[test]
    fn hedge_races_slow_primary_and_fast_fallback_wins() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1500, 97)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            hedge_delay: Some(Duration::from_millis(25)),
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        r.register(
            "chaos",
            Arc::new(ChaosEngine::slow(Arc::clone(&brute), Duration::from_millis(400), 12)),
        );
        r.register("brute", brute);
        r.set_fallback_chain(vec!["brute".into()]);

        let t0 = std::time::Instant::now();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: None }) {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 5),
            other => panic!("{other:?}"),
        }
        // the hedge answered long before the 400ms primary finished
        assert!(t0.elapsed() < Duration::from_millis(300), "{:?}", t0.elapsed());
        let s = r.metrics().snapshot();
        assert_eq!(s.hedges, 1, "{s:?}");
        assert_eq!(s.hedge_wins, 1, "{s:?}");
        assert_eq!(s.fallbacks, 1, "{s:?}");
        assert_eq!(s.errors, 0, "{s:?}");
    }

    #[test]
    fn budget_bounds_slow_engine_without_per_attempt_deadline() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 98)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            budget: Some(Duration::from_millis(50)),
            fallback_enabled: false,
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        r.register(
            "chaos",
            Arc::new(ChaosEngine::slow(brute, Duration::from_millis(400), 13)),
        );
        let t0 = std::time::Instant::now();
        match r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { domain, .. } => assert_eq!(domain, "timeout"),
            other => panic!("{other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_millis(250), "{:?}", t0.elapsed());
        let s = r.metrics().snapshot();
        assert_eq!(s.budget_exhausted, 1, "{s:?}");
        assert!(s.timeouts >= 1, "{s:?}");
    }

    #[test]
    fn health_line_reports_state() {
        let r = router();
        match r.handle(&Request::Health) {
            Response::Text(t) => {
                assert!(t.contains("status=ok"), "{t}");
                assert!(t.contains("default=brute"), "{t}");
                assert!(t.contains("queue_depth=0"), "{t}");
                assert!(t.contains("engines=active,brute"), "{t}");
                assert!(t.contains("active:closed"), "{t}");
                assert!(t.contains("brute:closed"), "{t}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn health_line_reports_draining() {
        let r = router();
        r.metrics().set_draining(true);
        match r.handle(&Request::Health) {
            Response::Text(t) => assert!(t.contains("status=draining"), "{t}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn health_line_reports_recovering_then_ok() {
        let r = router();
        r.metrics().set_recovering(true);
        match r.handle(&Request::Health) {
            Response::Text(t) => assert!(t.contains("status=recovering"), "{t}"),
            other => panic!("{other:?}"),
        }
        // draining outranks recovering
        r.metrics().set_draining(true);
        match r.handle(&Request::Health) {
            Response::Text(t) => assert!(t.contains("status=draining"), "{t}"),
            other => panic!("{other:?}"),
        }
        r.metrics().set_draining(false);
        r.metrics().set_recovering(false);
        match r.handle(&Request::Health) {
            Response::Text(t) => assert!(t.contains("status=ok"), "{t}"),
            other => panic!("{other:?}"),
        }
    }
}
