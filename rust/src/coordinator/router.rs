//! Request router: owns the engine set and dispatches each request
//! through the resilience ladder — per-engine circuit breakers,
//! per-attempt deadlines, retry with backoff for transient faults, and
//! a fallback chain that degrades gracefully toward brute force.
//!
//! Engine *failures* (runtime errors, panics, deadline overruns) walk
//! the chain; *client* errors (bad k, unknown engine) are returned
//! immediately — no other engine can fix a malformed request.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::channel;
use std::sync::Arc;

use super::metrics::Metrics;
use super::protocol::{Request, Response};
use super::resilience::{is_client_error, is_retryable, CircuitBreaker, ResiliencePolicy};
use crate::engine::{Neighbor, NnEngine};
use crate::error::{AsnnError, Result};
use crate::util::timer::Timer;

/// Default degradation order: most specialised engine first, exact
/// brute-force scan as the engine of last resort.
pub const DEFAULT_FALLBACK_CHAIN: [&str; 4] = ["active-pjrt", "active", "kdtree", "brute"];

/// Engine registry + dispatch policy.
pub struct Router {
    engines: HashMap<String, Arc<dyn NnEngine>>,
    breakers: HashMap<String, CircuitBreaker>,
    fallback_chain: Vec<String>,
    policy: ResiliencePolicy,
    default_engine: String,
    metrics: Arc<Metrics>,
}

/// The engine-facing part of a request (small and `Copy` so it can be
/// re-sent to fallback engines and moved into deadline threads).
#[derive(Debug, Clone, Copy)]
enum Query {
    Knn { k: usize, x: f64, y: f64 },
    Classify { k: usize, x: f64, y: f64 },
}

enum Outcome {
    Hits(Vec<Neighbor>),
    Label(u16),
}

fn run_query(engine: &dyn NnEngine, q: Query) -> Result<Outcome> {
    match q {
        Query::Knn { k, x, y } => engine.knn(&[x, y], k).map(Outcome::Hits),
        Query::Classify { k, x, y } => engine.classify(&[x, y], k).map(Outcome::Label),
    }
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".into()
    }
}

impl Router {
    pub fn new(default_engine: impl Into<String>, metrics: Arc<Metrics>) -> Self {
        Self::with_policy(default_engine, metrics, ResiliencePolicy::default())
    }

    pub fn with_policy(
        default_engine: impl Into<String>,
        metrics: Arc<Metrics>,
        policy: ResiliencePolicy,
    ) -> Self {
        Self {
            engines: HashMap::new(),
            breakers: HashMap::new(),
            fallback_chain: DEFAULT_FALLBACK_CHAIN.iter().map(|s| s.to_string()).collect(),
            policy,
            default_engine: default_engine.into(),
            metrics,
        }
    }

    pub fn register(&mut self, name: impl Into<String>, engine: Arc<dyn NnEngine>) {
        let name = name.into();
        self.breakers.insert(name.clone(), CircuitBreaker::new(self.policy.breaker));
        self.engines.insert(name, engine);
    }

    /// Override the default degradation order (names absent from the
    /// registry are skipped at dispatch time).
    pub fn set_fallback_chain(&mut self, chain: Vec<String>) {
        self.fallback_chain = chain;
    }

    pub fn policy(&self) -> &ResiliencePolicy {
        &self.policy
    }

    pub fn engine_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.engines.keys().cloned().collect();
        v.sort();
        v
    }

    /// Breaker state per engine, sorted by name (for HEALTH probes).
    pub fn breaker_states(&self) -> Vec<(String, &'static str)> {
        let mut v: Vec<(String, &'static str)> =
            self.breakers.iter().map(|(n, b)| (n.clone(), b.state_name())).collect();
        v.sort();
        v
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Handle one request, recording metrics. Never panics; protocol
    /// and engine failures map to `Response::Error`.
    pub fn handle(&self, req: &Request) -> Response {
        match req {
            Request::Knn { k, x, y, engine } => {
                self.dispatch(Query::Knn { k: *k, x: *x, y: *y }, engine.as_deref())
            }
            Request::Classify { k, x, y, engine } => {
                self.dispatch(Query::Classify { k: *k, x: *x, y: *y }, engine.as_deref())
            }
            Request::Stats => Response::Text(self.metrics.snapshot().render()),
            Request::Health => Response::Text(self.health_line()),
            Request::Ping => Response::Text("pong".into()),
            Request::Quit => Response::Text("bye".into()),
        }
    }

    /// One-line readiness report: overall status, default engine,
    /// queue depth, engine set, and per-engine breaker states.
    fn health_line(&self) -> String {
        let breakers: Vec<String> = self
            .breaker_states()
            .into_iter()
            .map(|(n, s)| format!("{n}:{s}"))
            .collect();
        let default_open = self
            .breakers
            .get(&self.default_engine)
            .map(|b| b.is_open())
            .unwrap_or(true);
        format!(
            "status={} default={} queue_depth={} engines={} breakers={}",
            if default_open { "degraded" } else { "ok" },
            self.default_engine,
            self.metrics.inflight(),
            self.engine_names().join(","),
            breakers.join(","),
        )
    }

    /// The engines this request may use, in order: the requested one,
    /// then (if fallback is enabled) the registered chain entries.
    fn chain_for<'a>(&'a self, requested: &'a str) -> Vec<&'a str> {
        let mut chain = vec![requested];
        if self.policy.fallback_enabled {
            for name in &self.fallback_chain {
                if name != requested && self.engines.contains_key(name) {
                    chain.push(name.as_str());
                }
            }
        }
        chain
    }

    /// One engine attempt, guarded: panics are caught and surfaced as
    /// runtime errors; with a deadline set, the call runs on a helper
    /// thread and is abandoned (thread detaches, result discarded) if
    /// it overruns.
    fn guarded(&self, engine: &Arc<dyn NnEngine>, q: Query) -> Result<Outcome> {
        match self.policy.deadline {
            None => catch_unwind(AssertUnwindSafe(|| run_query(engine.as_ref(), q)))
                .unwrap_or_else(|p| {
                    self.metrics.record_panic();
                    Err(AsnnError::Runtime(format!("engine panicked: {}", panic_message(p))))
                }),
            Some(deadline) => {
                let (tx, rx) = channel();
                let engine = Arc::clone(engine);
                std::thread::Builder::new()
                    .name("asnn-deadline".into())
                    .spawn(move || {
                        let r = catch_unwind(AssertUnwindSafe(|| run_query(engine.as_ref(), q)))
                            .unwrap_or_else(|p| {
                                Err(AsnnError::Runtime(format!(
                                    "engine panicked: {}",
                                    panic_message(p)
                                )))
                            });
                        let _ = tx.send(r);
                    })
                    .map_err(|e| {
                        AsnnError::Coordinator(format!("spawn deadline thread: {e}"))
                    })?;
                match rx.recv_timeout(deadline) {
                    Ok(r) => {
                        if let Err(e) = &r {
                            if matches!(e, AsnnError::Runtime(m) if m.starts_with("engine panicked")) {
                                self.metrics.record_panic();
                            }
                        }
                        r
                    }
                    Err(_) => {
                        self.metrics.record_timeout();
                        Err(AsnnError::Timeout(format!(
                            "engine exceeded {}ms deadline",
                            deadline.as_millis()
                        )))
                    }
                }
            }
        }
    }

    /// Guarded attempt plus retry-with-backoff for transient failures.
    fn attempt(&self, engine: &Arc<dyn NnEngine>, q: Query) -> Result<Outcome> {
        let mut attempt = 0;
        loop {
            match self.guarded(engine, q) {
                Ok(out) => return Ok(out),
                Err(e) if is_retryable(&e) && attempt < self.policy.retry.max_retries => {
                    self.metrics.record_retry();
                    std::thread::sleep(self.policy.retry.backoff_for(attempt));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn dispatch(&self, q: Query, engine_override: Option<&str>) -> Response {
        let requested = engine_override.unwrap_or(&self.default_engine);
        if !self.engines.contains_key(requested) {
            self.metrics.record_error();
            return Response::from_error(&AsnnError::Coordinator(format!(
                "unknown engine {requested:?} (have: {})",
                self.engine_names().join(", ")
            )));
        }
        let t = Timer::new();
        let mut last_err: Option<AsnnError> = None;
        for name in self.chain_for(requested) {
            let breaker = &self.breakers[name];
            if !breaker.allow() {
                continue; // circuit open: skip without spending an attempt
            }
            match self.attempt(&self.engines[name], q) {
                Ok(out) => {
                    breaker.record_success();
                    if name != requested {
                        self.metrics.record_fallback();
                    }
                    return match out {
                        Outcome::Hits(hits) => {
                            self.metrics.record_knn(t.elapsed_ns());
                            Response::Neighbors(hits)
                        }
                        Outcome::Label(label) => {
                            self.metrics.record_classify(t.elapsed_ns());
                            Response::Label(label)
                        }
                    };
                }
                Err(e) if is_client_error(&e) => {
                    // the request itself is bad; no engine will do better
                    self.metrics.record_error();
                    return Response::from_error(&e);
                }
                Err(e) => {
                    if breaker.record_failure() {
                        self.metrics.record_trip();
                    }
                    last_err = Some(e);
                }
            }
        }
        self.metrics.record_error();
        let err = last_err.unwrap_or_else(|| {
            AsnnError::Coordinator("no engine available: all circuits open".into())
        });
        Response::from_error(&err)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::resilience::{BreakerPolicy, RetryPolicy};
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::engine::active::{ActiveEngine, ActiveParams};
    use crate::engine::brute::BruteEngine;
    use crate::engine::chaos::ChaosEngine;
    use std::time::Duration;

    fn router() -> Router {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(2000, 91)));
        let mut r = Router::new("brute", Arc::new(Metrics::new()));
        r.register("brute", Arc::new(BruteEngine::new(ds.clone())));
        r.register(
            "active",
            Arc::new(ActiveEngine::new(ds, 500, ActiveParams::default()).unwrap()),
        );
        r
    }

    #[test]
    fn routes_to_default_engine() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: None }) {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 5),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().knn_requests, 1);
    }

    #[test]
    fn routes_to_override_engine() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: Some("active".into()) }) {
            Response::Neighbors(hits) => assert!(hits.len() <= 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_engine_is_protocol_error() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: Some("nope".into()) }) {
            Response::Error { domain, .. } => assert_eq!(domain, "coordinator"),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().errors, 1);
    }

    #[test]
    fn classify_and_stats() {
        let r = router();
        match r.handle(&Request::Classify { k: 11, x: 0.3, y: 0.7, engine: None }) {
            Response::Label(l) => assert!(l < 3),
            other => panic!("{other:?}"),
        }
        match r.handle(&Request::Stats) {
            Response::Text(t) => assert!(t.contains("classify=1")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn engine_error_propagates_as_response() {
        let r = router();
        match r.handle(&Request::Knn { k: 0, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { domain, .. } => assert_eq!(domain, "query"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn client_errors_do_not_fall_back_or_trip() {
        // bad k through a healthy chain: query error returned as-is,
        // breakers untouched, no fallback recorded
        let r = router();
        match r.handle(&Request::Knn { k: 0, x: 0.5, y: 0.5, engine: Some("active".into()) }) {
            Response::Error { domain, .. } => assert_eq!(domain, "query"),
            other => panic!("{other:?}"),
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.fallbacks, 0);
        assert_eq!(s.breaker_trips, 0);
        assert!(r.breaker_states().iter().all(|(_, s)| *s == "closed"));
    }

    #[test]
    fn failing_engine_falls_back_and_trips_breaker() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 92)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            breaker: BreakerPolicy { threshold: 3, cooldown: Duration::from_secs(60) },
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        r.register("chaos", Arc::new(ChaosEngine::failing(Arc::clone(&brute), 7)));
        r.register("brute", brute);
        r.set_fallback_chain(vec!["brute".into()]);

        for _ in 0..5 {
            match r.handle(&Request::Knn { k: 4, x: 0.5, y: 0.5, engine: None }) {
                Response::Neighbors(hits) => assert_eq!(hits.len(), 4),
                other => panic!("{other:?}"),
            }
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.fallbacks, 5);
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.errors, 0);
        assert!(r
            .breaker_states()
            .iter()
            .any(|(n, st)| n == "chaos" && *st == "open"));
    }

    #[test]
    fn panicking_engine_is_isolated() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 93)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let mut r = Router::new("chaos", Arc::new(Metrics::new()));
        r.register("chaos", Arc::new(ChaosEngine::panicking(Arc::clone(&brute), 8)));
        r.register("brute", brute);
        r.set_fallback_chain(vec!["brute".into()]);
        match r.handle(&Request::Classify { k: 5, x: 0.4, y: 0.4, engine: None }) {
            Response::Label(l) => assert!(l < 3),
            other => panic!("{other:?}"),
        }
        let s = r.metrics().snapshot();
        assert_eq!(s.panics, 1);
        assert_eq!(s.fallbacks, 1);
    }

    #[test]
    fn deadline_converts_slow_engine_to_timeout() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 94)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            deadline: Some(Duration::from_millis(25)),
            fallback_enabled: false,
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        r.register(
            "chaos",
            Arc::new(ChaosEngine::slow(brute, Duration::from_millis(300), 9)),
        );
        match r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { domain, .. } => assert_eq!(domain, "timeout"),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().timeouts, 1);
    }

    #[test]
    fn transient_errors_are_retried() {
        // error_rate 0.5: with 4 retries per request, 20 requests all
        // succeed with overwhelming probability, and retries are counted
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 95)));
        let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
        let policy = ResiliencePolicy {
            retry: RetryPolicy { max_retries: 4, backoff: Duration::from_micros(100) },
            fallback_enabled: false,
            breaker: BreakerPolicy { threshold: 1000, cooldown: Duration::from_secs(60) },
            ..ResiliencePolicy::default()
        };
        let mut r = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
        let chaos = ChaosEngine::new(
            brute,
            crate::engine::chaos::ChaosConfig {
                error_rate: 0.5,
                seed: 10,
                ..Default::default()
            },
        );
        r.register("chaos", Arc::new(chaos));
        let mut ok = 0;
        for _ in 0..20 {
            if let Response::Neighbors(_) =
                r.handle(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None })
            {
                ok += 1;
            }
        }
        let s = r.metrics().snapshot();
        assert!(ok >= 18, "ok={ok}");
        assert!(s.retries > 0, "{s:?}");
    }

    #[test]
    fn health_line_reports_state() {
        let r = router();
        match r.handle(&Request::Health) {
            Response::Text(t) => {
                assert!(t.contains("status=ok"), "{t}");
                assert!(t.contains("default=brute"), "{t}");
                assert!(t.contains("queue_depth=0"), "{t}");
                assert!(t.contains("engines=active,brute"), "{t}");
                assert!(t.contains("active:closed"), "{t}");
                assert!(t.contains("brute:closed"), "{t}");
            }
            other => panic!("{other:?}"),
        }
    }
}
