//! Request router: owns the engine set and dispatches each request to
//! the default engine or a per-request override.

use std::collections::HashMap;
use std::sync::Arc;

use super::metrics::Metrics;
use super::protocol::{Request, Response};
use crate::engine::NnEngine;
use crate::error::{AsnnError, Result};
use crate::util::timer::Timer;

/// Engine registry + dispatch policy.
pub struct Router {
    engines: HashMap<String, Arc<dyn NnEngine>>,
    default_engine: String,
    metrics: Arc<Metrics>,
}

impl Router {
    pub fn new(default_engine: impl Into<String>, metrics: Arc<Metrics>) -> Self {
        Self { engines: HashMap::new(), default_engine: default_engine.into(), metrics }
    }

    pub fn register(&mut self, name: impl Into<String>, engine: Arc<dyn NnEngine>) {
        self.engines.insert(name.into(), engine);
    }

    pub fn engine_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.engines.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    fn pick(&self, name: Option<&str>) -> Result<&Arc<dyn NnEngine>> {
        let name = name.unwrap_or(&self.default_engine);
        self.engines.get(name).ok_or_else(|| {
            AsnnError::Coordinator(format!(
                "unknown engine {name:?} (have: {})",
                self.engine_names().join(", ")
            ))
        })
    }

    /// Handle one request, recording metrics. Never panics; protocol
    /// and engine failures map to `Response::Error`.
    pub fn handle(&self, req: &Request) -> Response {
        match req {
            Request::Knn { k, x, y, engine } => {
                let t = Timer::new();
                match self.pick(engine.as_deref()).and_then(|e| e.knn(&[*x, *y], *k)) {
                    Ok(hits) => {
                        self.metrics.record_knn(t.elapsed_ns());
                        Response::Neighbors(hits)
                    }
                    Err(e) => {
                        self.metrics.record_error();
                        Response::from_error(&e)
                    }
                }
            }
            Request::Classify { k, x, y, engine } => {
                let t = Timer::new();
                match self.pick(engine.as_deref()).and_then(|e| e.classify(&[*x, *y], *k)) {
                    Ok(label) => {
                        self.metrics.record_classify(t.elapsed_ns());
                        Response::Label(label)
                    }
                    Err(e) => {
                        self.metrics.record_error();
                        Response::from_error(&e)
                    }
                }
            }
            Request::Stats => Response::Text(self.metrics.snapshot().render()),
            Request::Ping => Response::Text("pong".into()),
            Request::Quit => Response::Text("bye".into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::engine::brute::BruteEngine;
    use crate::engine::active::{ActiveEngine, ActiveParams};

    fn router() -> Router {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(2000, 91)));
        let mut r = Router::new("brute", Arc::new(Metrics::new()));
        r.register("brute", Arc::new(BruteEngine::new(ds.clone())));
        r.register(
            "active",
            Arc::new(ActiveEngine::new(ds, 500, ActiveParams::default()).unwrap()),
        );
        r
    }

    #[test]
    fn routes_to_default_engine() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: None }) {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 5),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().knn_requests, 1);
    }

    #[test]
    fn routes_to_override_engine() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: Some("active".into()) }) {
            Response::Neighbors(hits) => assert!(hits.len() <= 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_engine_is_protocol_error() {
        let r = router();
        match r.handle(&Request::Knn { k: 5, x: 0.5, y: 0.5, engine: Some("nope".into()) }) {
            Response::Error { domain, .. } => assert_eq!(domain, "coordinator"),
            other => panic!("{other:?}"),
        }
        assert_eq!(r.metrics().snapshot().errors, 1);
    }

    #[test]
    fn classify_and_stats() {
        let r = router();
        match r.handle(&Request::Classify { k: 11, x: 0.3, y: 0.7, engine: None }) {
            Response::Label(l) => assert!(l < 3),
            other => panic!("{other:?}"),
        }
        match r.handle(&Request::Stats) {
            Response::Text(t) => assert!(t.contains("classify=1")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn engine_error_propagates_as_response() {
        let r = router();
        match r.handle(&Request::Knn { k: 0, x: 0.5, y: 0.5, engine: None }) {
            Response::Error { domain, .. } => assert_eq!(domain, "query"),
            other => panic!("{other:?}"),
        }
    }
}
