//! L3 serving coordinator: the production wrapper around the engines.
//!
//! ```text
//! TCP clients ──► server ──► admission control ──► worker pool
//!                   │         (shed + ERR overload   (panic-isolated:
//!                   │          when queue ≥ limit)    catch_unwind +
//!                   │                                 respawn backstop)
//!                   │                                      │
//!                   │                                      ▼
//!                   │                                   router
//!                   │                                      │
//!                   │              ┌───────────────────────┤
//!                   │              ▼                       ▼
//!                   │      circuit breakers        request budget +
//!                   │      (per engine, trip        deadline + retry
//!                   │       after N failures;      (one Budget shared by
//!                   │       close after M probe     retries, backoff and
//!                   │       successes)              fallback hops)
//!                   │              │                       │
//!                   │              ├───────► hedged dispatch
//!                   │              │  (race the next healthy engine
//!                   │              │   after hedge_delay; first
//!                   │              │   success wins)
//!                   │              └───────► engine fallback chain
//!                   │                 (active_pjrt → active → kdtree → brute)
//!                   │
//!                   ├── metrics ◄── trips / sheds / fallbacks / panics /
//!                   │               hedges / budget_exhausted /
//!                   │               batches / expired_dropped / draining
//!                   └── batching lane ──► router (engine-less KNNs are
//!                       grouped by a deadline batcher and dispatched as
//!                       one KNNB-style batch; the batch fans across a
//!                       dedicated pool, budget-expired items drop with
//!                       a timeout to their waiter)
//! ```
//!
//! Shutdown drains: `ServerHandle::shutdown` stops accepting, reports
//! `status=draining` via HEALTH, lets in-flight connections finish up
//! to a drain deadline, then force-closes.
//!
//! Durability: the [`snapshotter`] keeps checksummed snapshots of the
//! serving dataset and grid index in the `[store]` directory (see
//! `crate::store`), so a crashed server warm-restarts from its last
//! valid generation instead of regenerating and re-rasterizing.
//! During the boot recovery pass HEALTH reports `status=recovering`.
//!
//! Everything is std-only (tokio is not in the offline vendor set):
//! a thread-pool accept loop, `mpsc`-based batching, and atomic
//! counters + a mutexed latency histogram for metrics. The
//! [`resilience`] module holds the failure-handling primitives; the
//! [`crate::engine::chaos`] engine injects faults so every path above
//! is testable end-to-end (see `tests/chaos_e2e.rs`).

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod resilience;
pub mod router;
pub mod server;
pub mod snapshotter;
pub mod worker;

pub use metrics::Metrics;
pub use protocol::{BatchEntry, ErrCode, Request, Response, StatsFormat, StatsSection};
pub use resilience::{Budget, CircuitBreaker, ResiliencePolicy};
pub use router::Router;
pub use server::{IoLimits, Server};
pub use snapshotter::{SnapshotSource, Snapshotter};
pub use worker::ThreadPool;
