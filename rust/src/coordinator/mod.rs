//! L3 serving coordinator: the production wrapper around the engines.
//!
//! ```text
//! TCP clients ──► server (line protocol) ──► router ──► engine
//!                     │                        │
//!                     └── metrics ◄────────────┘
//!                     └── batcher (groups same-window PJRT queries)
//! ```
//!
//! Everything is std-only (tokio is not in the offline vendor set):
//! a thread-pool accept loop, `mpsc`-based batching, and atomic
//! counters + a mutexed latency histogram for metrics.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod router;
pub mod server;
pub mod worker;

pub use metrics::Metrics;
pub use protocol::{Request, Response};
pub use router::Router;
pub use server::Server;
