//! TCP server: newline-delimited protocol over std::net, connections
//! handled by the worker pool, graceful shutdown via an atomic flag.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::protocol::{Request, Response};
use super::router::Router;
use super::worker::ThreadPool;
use crate::error::{AsnnError, Result};

/// The serving frontend.
pub struct Server {
    router: Arc<Router>,
    workers: usize,
}

/// Handle for stopping a running server.
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and wait for the accept loop to exit.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // nudge the blocking accept() with a no-op connection
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Server {
    pub fn new(router: Arc<Router>, workers: usize) -> Self {
        Self { router, workers: workers.max(1) }
    }

    /// Bind and serve in a background thread; returns a stop handle.
    /// `addr` may use port 0 for an OS-assigned port (tests).
    pub fn spawn(self, addr: &str) -> Result<ServerHandle> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| AsnnError::Coordinator(format!("bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| AsnnError::Coordinator(format!("local_addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let router = Arc::clone(&self.router);
        let workers = self.workers;
        let join = std::thread::Builder::new()
            .name("asnn-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let router = Arc::clone(&router);
                            let stop = Arc::clone(&stop2);
                            pool.execute(move || {
                                let _ = handle_connection(stream, &router, &stop);
                            });
                        }
                        Err(_) => continue,
                    }
                }
            })
            .map_err(|e| AsnnError::Coordinator(format!("spawn accept loop: {e}")))?;
        Ok(ServerHandle { addr: local, stop, join: Some(join) })
    }
}

/// Serve one connection until QUIT/EOF/server-stop. Reads use a short
/// timeout so idle connections observe the stop flag — otherwise a
/// worker blocked in `read_line` would deadlock server shutdown while
/// any client keeps its connection open.
fn handle_connection(
    stream: TcpStream,
    router: &Router,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100))).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // keep any partial line already buffered; just poll stop
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(e) => return Err(e),
        }
        let msg = std::mem::take(&mut line);
        if msg.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(msg.trim_end()) {
            Ok(Request::Quit) => {
                writeln!(writer, "{}", Response::Text("bye".into()).format())?;
                writer.flush()?;
                break;
            }
            Ok(req) => router.handle(&req),
            Err(e) => {
                router.metrics().record_error();
                Response::from_error(&e)
            }
        };
        writeln!(writer, "{}", response.format())?;
        writer.flush()?;
    }
    Ok(())
}

/// Minimal blocking client for tests, examples, and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| AsnnError::Coordinator(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(
            stream
                .try_clone()
                .map_err(|e| AsnnError::Coordinator(format!("clone stream: {e}")))?,
        );
        Ok(Self { reader, writer: BufWriter::new(stream) })
    }

    /// Send one request, read one response line.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", req.format())?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(AsnnError::Coordinator("server closed connection".into()));
        }
        Response::parse(line.trim_end())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::engine::brute::BruteEngine;

    fn spawn_server() -> ServerHandle {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 101)));
        let mut router = Router::new("brute", Arc::new(Metrics::new()));
        router.register("brute", Arc::new(BruteEngine::new(ds)));
        Server::new(Arc::new(router), 2).spawn("127.0.0.1:0").unwrap()
    }

    #[test]
    fn end_to_end_knn() {
        let handle = spawn_server();
        let mut client = Client::connect(&handle.addr).unwrap();
        match client.call(&Request::Knn { k: 7, x: 0.5, y: 0.5, engine: None }).unwrap() {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 7),
            other => panic!("{other:?}"),
        }
        handle.shutdown();
    }

    #[test]
    fn ping_stats_and_errors() {
        let handle = spawn_server();
        let mut client = Client::connect(&handle.addr).unwrap();
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Text("pong".into()));
        match client.call(&Request::Knn { k: 0, x: 0.0, y: 0.0, engine: None }).unwrap() {
            Response::Error { domain, .. } => assert_eq!(domain, "query"),
            other => panic!("{other:?}"),
        }
        match client.call(&Request::Stats).unwrap() {
            Response::Text(t) => assert!(t.contains("errors=1"), "{t}"),
            other => panic!("{other:?}"),
        }
        handle.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let handle = spawn_server();
        let addr = handle.addr;
        let threads: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    for _ in 0..10 {
                        match c.call(&Request::Knn { k: 3, x: 0.2, y: 0.8, engine: None }) {
                            Ok(Response::Neighbors(h)) => assert_eq!(h.len(), 3),
                            other => panic!("{other:?}"),
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        handle.shutdown();
    }

    #[test]
    fn malformed_line_gets_err_response() {
        let handle = spawn_server();
        let stream = TcpStream::connect(handle.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writeln!(writer, "GIBBERISH 1 2").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR protocol"), "{line}");
        handle.shutdown();
    }
}
