//! TCP server: newline-delimited protocol over std::net, connections
//! handled by a panic-isolated worker pool, graceful shutdown via an
//! atomic flag.
//!
//! Resilience: admission control sheds connections with a structured
//! `ERR overload` line once the in-flight count reaches the configured
//! limit (instead of queueing unboundedly), failed `accept()` calls are
//! counted and backed off (no hot-looping on a sick listener), and a
//! job that cannot be queued on a shut-down pool is dropped with an
//! error counter rather than panicking the accept loop.
//!
//! Hostile-input hardening ([`IoLimits`]): request lines are length-
//! capped at `max_line_bytes` — an oversized line gets a structured
//! `ERR too-long` and the connection closes, with at most one buffer's
//! worth of the flood ever held in memory (counter:
//! `oversize_rejected`). A per-connection idle deadline measures time
//! to a *complete* line, so a slow-loris client dribbling bytes
//! forever is disconnected just like a silent one (counter:
//! `idle_disconnects`). Response writes are bounded by a write timeout;
//! a client that stops reading is dropped (counter:
//! `write_timeout_disconnects`).
//!
//! Shutdown is a two-phase drain: `ServerHandle::shutdown` first flips
//! the draining flag (listener closes, HEALTH reports
//! `status=draining`, connections finish their current request and
//! close), waits up to `drain_deadline` for in-flight connections to
//! reach zero, then sets the hard stop flag and joins. Both `shutdown`
//! and `Drop` funnel through one idempotent `stop_and_join`, so
//! double-shutdown and shutdown-then-drop are safe.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::protocol::{ErrCode, Request, Response};
use super::router::Router;
use super::worker::ThreadPool;
use crate::error::{AsnnError, Result};

/// Per-connection I/O limits (wire-level hostile-input defenses).
#[derive(Debug, Clone, Copy)]
pub struct IoLimits {
    /// Socket read timeout. Doubles as the poll interval at which an
    /// idle connection observes the stop/drain flags, so keep it small.
    pub read_timeout: Duration,
    /// Socket write timeout; a client that stops reading its responses
    /// is disconnected after this long.
    pub write_timeout: Duration,
    /// Close a connection that has not delivered a *complete* request
    /// line for this long (slow-loris defense). `Duration::ZERO`
    /// disables the idle deadline.
    pub idle_timeout: Duration,
    /// Maximum request line length; longer lines are rejected with
    /// `ERR too-long` and the connection closes.
    pub max_line_bytes: usize,
}

impl Default for IoLimits {
    fn default() -> Self {
        Self {
            read_timeout: Duration::from_millis(100),
            write_timeout: Duration::from_millis(100),
            idle_timeout: Duration::from_secs(30),
            max_line_bytes: 64 * 1024,
        }
    }
}

/// The serving frontend.
pub struct Server {
    router: Arc<Router>,
    workers: usize,
    /// Admission limit: connections admitted but not yet finished.
    /// 0 = unlimited (no shedding).
    max_inflight: usize,
    /// How long shutdown waits for in-flight connections to finish
    /// before force-closing them.
    drain_deadline: Duration,
    /// Per-connection wire limits.
    limits: IoLimits,
}

/// Decrements the in-flight gauge when a connection finishes, even if
/// its handler panics (the guard drops during unwind).
struct InflightGuard(Arc<Metrics>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.exit_inflight();
    }
}

/// Handle for stopping a running server.
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    drain_deadline: Duration,
    metrics: Arc<Metrics>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Graceful shutdown: stop accepting, let in-flight connections
    /// finish up to the drain deadline, then force-close and join.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    /// The idempotent core shared by `shutdown` and `Drop`: a second
    /// call (or a drop after shutdown) finds `join` already taken and
    /// returns immediately.
    fn stop_and_join(&mut self) {
        let Some(join) = self.join.take() else { return };
        // phase 1: drain. New connections stop being accepted, HEALTH
        // reports status=draining, existing connections close after
        // their current request.
        self.draining.store(true, Ordering::SeqCst);
        self.metrics.set_draining(true);
        // nudge the blocking accept() with a no-op connection
        let _ = TcpStream::connect(self.addr);
        let t0 = Instant::now();
        while self.metrics.inflight() > 0 && t0.elapsed() < self.drain_deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        // phase 2: hard stop for anything that outlived the deadline.
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        let _ = join.join();
        self.metrics.set_draining(false);
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

impl Server {
    pub fn new(router: Arc<Router>, workers: usize) -> Self {
        Self {
            router,
            workers: workers.max(1),
            max_inflight: 0,
            drain_deadline: Duration::from_millis(500),
            limits: IoLimits::default(),
        }
    }

    /// Shed connections once `n` are in flight (0 = unlimited).
    pub fn with_max_inflight(mut self, n: usize) -> Self {
        self.max_inflight = n;
        self
    }

    /// How long `shutdown` waits for in-flight connections before
    /// force-closing them.
    pub fn with_drain_deadline(mut self, d: Duration) -> Self {
        self.drain_deadline = d;
        self
    }

    /// Per-connection wire limits (timeouts, line cap).
    pub fn with_io_limits(mut self, limits: IoLimits) -> Self {
        self.limits = limits;
        self
    }

    /// Bind and serve in a background thread; returns a stop handle.
    /// `addr` may use port 0 for an OS-assigned port (tests).
    pub fn spawn(self, addr: &str) -> Result<ServerHandle> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| AsnnError::Coordinator(format!("bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| AsnnError::Coordinator(format!("local_addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let draining2 = Arc::clone(&draining);
        let handle_metrics = Arc::clone(self.router.metrics());
        let router = Arc::clone(&self.router);
        let workers = self.workers;
        let max_inflight = self.max_inflight;
        let limits = self.limits;
        let join = std::thread::Builder::new()
            .name("asnn-accept".into())
            .spawn(move || {
                let metrics = Arc::clone(router.metrics());
                let pool_metrics = Arc::clone(&metrics);
                let pool = ThreadPool::with_observer(
                    workers,
                    Arc::new(move || pool_metrics.record_panic()),
                );
                let mut accept_failures = 0u32;
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) || draining2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            accept_failures = 0;
                            if max_inflight > 0
                                && metrics.inflight() >= max_inflight as u64
                            {
                                shed(stream, &metrics, limits.write_timeout);
                                continue;
                            }
                            metrics.enter_inflight();
                            let guard = InflightGuard(Arc::clone(&metrics));
                            let conn_router = Arc::clone(&router);
                            let conn_stop = Arc::clone(&stop2);
                            let conn_draining = Arc::clone(&draining2);
                            let queued = pool.execute(move || {
                                let _inflight = guard;
                                let _ = handle_connection(
                                    stream,
                                    &conn_router,
                                    &conn_stop,
                                    &conn_draining,
                                    limits,
                                );
                            });
                            if queued.is_err() {
                                // shutdown raced the accept loop: the job
                                // (and its guard) was dropped, connection
                                // closed; count it instead of crashing
                                metrics.record_error();
                            }
                        }
                        Err(_) => {
                            // count and back off instead of hot-looping on
                            // a listener stuck returning errors
                            metrics.record_accept_error();
                            accept_failures = accept_failures.saturating_add(1);
                            let backoff_ms =
                                (1u64 << accept_failures.min(7)).min(100);
                            std::thread::sleep(Duration::from_millis(backoff_ms));
                        }
                    }
                }
                // close the listening port before joining the pool so a
                // draining server stops looking connectable right away
                drop(listener);
            })
            .map_err(|e| AsnnError::Coordinator(format!("spawn accept loop: {e}")))?;
        Ok(ServerHandle {
            addr: local,
            stop,
            draining,
            drain_deadline: self.drain_deadline,
            metrics: handle_metrics,
            join: Some(join),
        })
    }
}

/// Reject a connection with a structured overload error so clients can
/// distinguish "retry later" from a dead server. Bounded by a write
/// timeout so a slow client cannot stall the accept loop.
fn shed(stream: TcpStream, metrics: &Metrics, write_timeout: Duration) {
    metrics.record_shed();
    stream.set_write_timeout(Some(write_timeout)).ok();
    let mut writer = BufWriter::new(stream);
    let resp = Response::from_error(&AsnnError::Overloaded(
        "server at capacity; retry later".into(),
    ));
    let _ = write_line(&mut writer, metrics, &resp.format());
}

/// Outcome of one buffered read step of the bounded line reader.
enum LineStep {
    /// A complete line is ready in the accumulator.
    Line,
    /// Peer closed the connection with nothing buffered (a trailing
    /// unterminated line is reported as `Line` first).
    Eof,
    /// The line exceeded `max_line_bytes` before its newline arrived.
    TooLong,
    /// Progress was made (or a buffer boundary hit) but no newline yet.
    NeedMore,
}

/// One `fill_buf` round of reading a newline-terminated line into
/// `acc` without ever holding more than `max_bytes` of it. Returning
/// after every round (instead of looping internally) lets the caller
/// run its idle-deadline and shutdown checks between rounds — a
/// slow-loris client dribbling one byte per poll can't hide inside a
/// blocking read loop. `WouldBlock`/`TimedOut` propagate as errors
/// with `acc` preserved.
fn line_step(
    reader: &mut BufReader<TcpStream>,
    acc: &mut Vec<u8>,
    max_bytes: usize,
) -> std::io::Result<LineStep> {
    let (used, step) = {
        let buf = reader.fill_buf()?;
        if buf.is_empty() {
            // EOF: a trailing unterminated line still gets processed
            (0, if acc.is_empty() { LineStep::Eof } else { LineStep::Line })
        } else if let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            acc.extend_from_slice(&buf[..pos]);
            (pos + 1, LineStep::Line)
        } else {
            let n = buf.len();
            acc.extend_from_slice(buf);
            (n, LineStep::NeedMore)
        }
    };
    reader.consume(used);
    if acc.len() > max_bytes {
        return Ok(LineStep::TooLong);
    }
    Ok(step)
}

/// Write one response line, counting a timed-out write as a
/// `write_timeout_disconnects` before propagating the error (the
/// caller drops the connection).
fn write_line(
    writer: &mut BufWriter<TcpStream>,
    metrics: &Metrics,
    text: &str,
) -> std::io::Result<()> {
    let result = writeln!(writer, "{text}").and_then(|()| writer.flush());
    if let Err(ref e) = result {
        if e.kind() == std::io::ErrorKind::WouldBlock
            || e.kind() == std::io::ErrorKind::TimedOut
        {
            metrics.record_write_timeout_disconnect();
        }
    }
    result
}

/// Serve one connection until QUIT/EOF/server-stop. Reads use a short
/// timeout so idle connections observe the stop and drain flags —
/// otherwise a worker blocked reading would deadlock server shutdown
/// while any client keeps its connection open. While draining, the
/// current request is still answered, then the connection closes.
///
/// Wire hardening (see [`IoLimits`]): the idle clock measures time
/// since the last *complete* request line, so both silent connections
/// and byte-dribbling slow-loris clients hit the deadline; request
/// lines longer than `max_line_bytes` are answered with `ERR
/// too-long` and the connection closes.
fn handle_connection(
    stream: TcpStream,
    router: &Router,
    stop: &AtomicBool,
    draining: &AtomicBool,
    limits: IoLimits,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(limits.read_timeout)).ok();
    stream.set_write_timeout(Some(limits.write_timeout)).ok();
    let metrics = router.metrics();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut acc: Vec<u8> = Vec::new();
    let mut last_complete = Instant::now();
    let idle_expired = |since: &Instant| {
        limits.idle_timeout > Duration::ZERO && since.elapsed() >= limits.idle_timeout
    };
    loop {
        match line_step(&mut reader, &mut acc, limits.max_line_bytes) {
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // keep any partial line already buffered; poll the
                // shutdown flags and the idle deadline
                if stop.load(Ordering::SeqCst) || draining.load(Ordering::SeqCst) {
                    break;
                }
                if idle_expired(&last_complete) {
                    metrics.record_idle_disconnect();
                    break;
                }
                continue;
            }
            Err(e) => return Err(e),
            Ok(LineStep::Eof) => break,
            Ok(LineStep::TooLong) => {
                metrics.record_oversize_rejected();
                let resp = Response::Error {
                    code: ErrCode::TooLong,
                    message: format!(
                        "request line exceeds {} bytes",
                        limits.max_line_bytes
                    ),
                };
                let _ = write_line(&mut writer, metrics, &resp.format());
                break;
            }
            Ok(LineStep::NeedMore) => {
                // bytes arrived but no complete line: the idle clock
                // keeps running, so a dribbling client still expires
                if idle_expired(&last_complete) {
                    metrics.record_idle_disconnect();
                    break;
                }
                continue;
            }
            Ok(LineStep::Line) => {}
        }
        let msg = String::from_utf8_lossy(&acc).into_owned();
        acc.clear();
        last_complete = Instant::now();
        if msg.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(msg.trim_end()) {
            Ok(Request::Quit) => {
                write_line(&mut writer, metrics, &Response::Text("bye".into()).format())?;
                break;
            }
            Ok(req) => router.handle(&req),
            Err(e) => {
                metrics.record_error();
                Response::from_error(&e)
            }
        };
        write_line(&mut writer, metrics, &response.format())?;
        // graceful drain: this request was answered; close instead of
        // waiting for the next one
        if stop.load(Ordering::SeqCst) || draining.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

/// Minimal blocking client for tests, examples, and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| AsnnError::Coordinator(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(
            stream
                .try_clone()
                .map_err(|e| AsnnError::Coordinator(format!("clone stream: {e}")))?,
        );
        Ok(Self { reader, writer: BufWriter::new(stream) })
    }

    /// Send one request, read one response line.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", req.format())?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(AsnnError::Coordinator("server closed connection".into()));
        }
        Response::parse(line.trim_end())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::engine::brute::BruteEngine;

    fn spawn_server() -> ServerHandle {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 101)));
        let mut router = Router::new("brute", Arc::new(Metrics::new()));
        router.register("brute", Arc::new(BruteEngine::new(ds)));
        Server::new(Arc::new(router), 2).spawn("127.0.0.1:0").unwrap()
    }

    #[test]
    fn end_to_end_knn() {
        let handle = spawn_server();
        let mut client = Client::connect(&handle.addr).unwrap();
        match client.call(&Request::Knn { k: 7, x: 0.5, y: 0.5, engine: None }).unwrap() {
            Response::Neighbors(hits) => assert_eq!(hits.len(), 7),
            other => panic!("{other:?}"),
        }
        handle.shutdown();
    }

    #[test]
    fn ping_stats_and_errors() {
        let handle = spawn_server();
        let mut client = Client::connect(&handle.addr).unwrap();
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Text("pong".into()));
        match client.call(&Request::Knn { k: 0, x: 0.0, y: 0.0, engine: None }).unwrap() {
            Response::Error { code, .. } => assert_eq!(code, ErrCode::Query),
            other => panic!("{other:?}"),
        }
        match client.call(&Request::Stats).unwrap() {
            Response::Text(t) => assert!(t.contains("errors=1"), "{t}"),
            other => panic!("{other:?}"),
        }
        handle.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let handle = spawn_server();
        let addr = handle.addr;
        let threads: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    for _ in 0..10 {
                        match c.call(&Request::Knn { k: 3, x: 0.2, y: 0.8, engine: None }) {
                            Ok(Response::Neighbors(h)) => assert_eq!(h.len(), 3),
                            other => panic!("{other:?}"),
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        handle.shutdown();
    }

    #[test]
    fn overload_sheds_with_structured_error_then_recovers() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 106)));
        let mut router = Router::new("brute", Arc::new(Metrics::new()));
        router.register("brute", Arc::new(BruteEngine::new(ds)));
        let router = Arc::new(router);
        let handle = Server::new(Arc::clone(&router), 1)
            .with_max_inflight(1)
            .spawn("127.0.0.1:0")
            .unwrap();

        // occupy the single admission slot (PING proves it's admitted)
        let mut holder = Client::connect(&handle.addr).unwrap();
        assert_eq!(holder.call(&Request::Ping).unwrap(), Response::Text("pong".into()));

        // second connection is shed with a structured overload error
        let mut extra = Client::connect(&handle.addr).unwrap();
        match extra.call(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }).unwrap() {
            Response::Error { code, message } => {
                assert_eq!(code, ErrCode::Overload);
                assert!(message.contains("retry"), "{message}");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(router.metrics().snapshot().shed, 1);

        // free the slot; the server recovers and admits new connections
        drop(holder);
        let mut ok = false;
        for _ in 0..50 {
            std::thread::sleep(std::time::Duration::from_millis(20));
            if let Ok(mut c) = Client::connect(&handle.addr) {
                if let Ok(Response::Text(t)) = c.call(&Request::Ping) {
                    assert_eq!(t, "pong");
                    ok = true;
                    break;
                }
            }
        }
        assert!(ok, "server did not recover after shed");
        handle.shutdown();
    }

    #[test]
    fn health_probe_over_tcp() {
        let handle = spawn_server();
        let mut client = Client::connect(&handle.addr).unwrap();
        match client.call(&Request::Health).unwrap() {
            Response::Text(t) => {
                assert!(t.contains("status=ok"), "{t}");
                assert!(t.contains("engines=brute"), "{t}");
                assert!(t.contains("brute:closed"), "{t}");
                // this connection is itself in flight
                assert!(t.contains("queue_depth=1"), "{t}");
            }
            other => panic!("{other:?}"),
        }
        handle.shutdown();
    }

    #[test]
    fn malformed_line_gets_err_response() {
        let handle = spawn_server();
        let stream = TcpStream::connect(handle.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writeln!(writer, "GIBBERISH 1 2").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR protocol"), "{line}");
        handle.shutdown();
    }

    #[test]
    fn drop_after_shutdown_is_safe() {
        // shutdown consumes the handle, but Drop still runs on it —
        // stop_and_join must be idempotent
        let handle = spawn_server();
        let addr = handle.addr;
        handle.shutdown();
        // and a plain drop without shutdown also stops the server
        let handle2 = spawn_server();
        drop(handle2);
        // both listeners are gone
        for a in [addr] {
            std::thread::sleep(Duration::from_millis(20));
            assert!(
                TcpStream::connect(a).is_err()
                    || Client::connect(&a)
                        .and_then(|mut c| c.call(&Request::Ping))
                        .is_err(),
                "server still serving after shutdown"
            );
        }
    }

    fn spawn_limited(limits: IoLimits) -> (ServerHandle, Arc<Router>) {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(500, 113)));
        let mut router = Router::new("brute", Arc::new(Metrics::new()));
        router.register("brute", Arc::new(BruteEngine::new(ds)));
        let router = Arc::new(router);
        let handle = Server::new(Arc::clone(&router), 2)
            .with_io_limits(limits)
            .spawn("127.0.0.1:0")
            .unwrap();
        (handle, router)
    }

    #[test]
    fn oversize_line_rejected_and_connection_closed() {
        let (handle, router) = spawn_limited(IoLimits {
            max_line_bytes: 64,
            ..IoLimits::default()
        });
        let stream = TcpStream::connect(handle.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        // 200 bytes, no newline: the cap must trip without one
        writer.write_all(&[b'A'; 200]).unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR too-long"), "{line}");
        assert!(line.contains("64"), "{line}");
        // server closed the connection after rejecting
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0);
        assert_eq!(router.metrics().snapshot().oversize_rejected, 1);
        handle.shutdown();
    }

    #[test]
    fn idle_connection_disconnected_after_deadline() {
        let (handle, router) = spawn_limited(IoLimits {
            idle_timeout: Duration::from_millis(200),
            ..IoLimits::default()
        });
        let stream = TcpStream::connect(handle.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(stream);
        // send nothing; the server must hang up on its own
        let t0 = Instant::now();
        let mut line = String::new();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "expected EOF");
        assert!(t0.elapsed() < Duration::from_secs(3), "{:?}", t0.elapsed());
        assert_eq!(router.metrics().snapshot().idle_disconnects, 1);
        handle.shutdown();
    }

    #[test]
    fn slow_loris_dribble_is_disconnected() {
        let (handle, router) = spawn_limited(IoLimits {
            read_timeout: Duration::from_millis(50),
            idle_timeout: Duration::from_millis(250),
            ..IoLimits::default()
        });
        let stream = TcpStream::connect(handle.addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        // dribble one byte at a time, never completing a line; the
        // idle clock must not reset on partial progress
        for _ in 0..12 {
            let _ = writer.write_all(b"P");
            let _ = writer.flush();
            std::thread::sleep(Duration::from_millis(75));
        }
        let mut line = String::new();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "expected EOF");
        assert_eq!(router.metrics().snapshot().idle_disconnects, 1);
        handle.shutdown();
    }

    #[test]
    fn draining_connection_closes_after_current_request() {
        let handle = spawn_server();
        let mut client = Client::connect(&handle.addr).unwrap();
        assert_eq!(client.call(&Request::Ping).unwrap(), Response::Text("pong".into()));
        let t0 = Instant::now();
        handle.shutdown();
        // drain noticed the idle connection quickly (well under the
        // 500ms default deadline: the 100ms read poll sees the flag)
        assert!(t0.elapsed() < Duration::from_millis(450), "{:?}", t0.elapsed());
        // connection is now closed from the server side
        let r = client.call(&Request::Ping);
        assert!(r.is_err(), "{r:?}");
    }
}
