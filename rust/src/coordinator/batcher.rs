//! Deadline batcher: groups individually-submitted items into batches
//! of at most `batch_max`, flushing when full or when the oldest item
//! has waited `deadline`.
//!
//! The coordinator uses this to feed same-window-scale queries into the
//! `disk_count_w*_b16` PJRT artifacts — the paper's serial loop,
//! vectorized across concurrent clients.

use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Generic deadline batcher; `process` receives each flushed batch on a
/// dedicated thread.
pub struct Batcher<T: Send + 'static> {
    tx: Option<Sender<T>>,
    handle: Option<JoinHandle<()>>,
}

impl<T: Send + 'static> Batcher<T> {
    pub fn new(
        batch_max: usize,
        deadline: Duration,
        process: impl FnMut(Vec<T>) + Send + 'static,
    ) -> Self {
        assert!(batch_max > 0);
        let (tx, rx) = channel::<T>();
        let mut process = process;
        let handle = std::thread::Builder::new()
            .name("asnn-batcher".into())
            .spawn(move || {
                loop {
                    // block for the first item of a batch
                    let first = match rx.recv() {
                        Ok(item) => item,
                        Err(_) => break, // senders gone: shutdown
                    };
                    let mut batch = vec![first];
                    let flush_at = Instant::now() + deadline;
                    while batch.len() < batch_max {
                        let now = Instant::now();
                        if now >= flush_at {
                            break;
                        }
                        match rx.recv_timeout(flush_at - now) {
                            Ok(item) => batch.push(item),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => {
                                process(batch);
                                return;
                            }
                        }
                    }
                    process(batch);
                }
            })
            .expect("spawn batcher");
        Self { tx: Some(tx), handle: Some(handle) }
    }

    /// Submit one item; returns false if the batcher has shut down.
    pub fn submit(&self, item: T) -> bool {
        match &self.tx {
            Some(tx) => tx.send(item).is_ok(),
            None => false,
        }
    }
}

impl<T: Send + 'static> Drop for Batcher<T> {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    fn collect_batches(
        batch_max: usize,
        deadline_ms: u64,
    ) -> (Batcher<u32>, Arc<Mutex<Vec<Vec<u32>>>>) {
        let sink: Arc<Mutex<Vec<Vec<u32>>>> = Arc::new(Mutex::new(Vec::new()));
        let s = Arc::clone(&sink);
        let b = Batcher::new(batch_max, Duration::from_millis(deadline_ms), move |batch| {
            s.lock().unwrap().push(batch);
        });
        (b, sink)
    }

    #[test]
    fn all_items_processed_exactly_once() {
        let (b, sink) = collect_batches(8, 5);
        for i in 0..100 {
            assert!(b.submit(i));
        }
        drop(b);
        let batches = sink.lock().unwrap();
        let mut all: Vec<u32> = batches.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn batches_respect_max() {
        let (b, sink) = collect_batches(4, 50);
        for i in 0..20 {
            b.submit(i);
        }
        drop(b);
        for batch in sink.lock().unwrap().iter() {
            assert!(batch.len() <= 4);
        }
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (b, sink) = collect_batches(1000, 20);
        b.submit(1);
        b.submit(2);
        std::thread::sleep(Duration::from_millis(120));
        {
            let batches = sink.lock().unwrap();
            assert_eq!(batches.len(), 1, "deadline flush missing: {batches:?}");
            assert_eq!(batches[0], vec![1, 2]);
        }
        drop(b);
    }

    #[test]
    fn submit_after_drop_reports_false() {
        let (b, _sink) = collect_batches(4, 5);
        drop(b);
        // can't call submit on a dropped value; instead verify a fresh
        // batcher whose thread exited: simulate via closed channel
        let (tx, _) = std::sync::mpsc::channel::<u32>();
        drop(tx);
        // nothing to assert beyond the drop path not hanging
    }
}
