//! Deadline batcher: groups individually-submitted items into batches
//! of at most `batch_max`, flushing when full or when the oldest item
//! has waited `deadline` *since it was submitted* — every item is
//! timestamped at enqueue, so time spent waiting in the channel counts
//! against the flush deadline instead of silently extending it.
//!
//! The coordinator uses this to feed same-window-scale queries into the
//! `disk_count_w*_b16` PJRT artifacts — the paper's serial loop,
//! vectorized across concurrent clients.
//!
//! With a per-item budget (`with_budget`), items that have already
//! waited longer than the budget at flush time are dropped and counted
//! instead of being processed — a batched query whose requester has
//! given up is pure wasted work downstream.
//!
//! A `process` closure that panics is caught and counted: the batch is
//! lost but the batcher thread survives, later batches still flush,
//! and `Drop` joins cleanly instead of wedging.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Generic deadline batcher; `process` receives each flushed batch on a
/// dedicated thread.
pub struct Batcher<T: Send + 'static> {
    tx: Option<Sender<(Instant, T)>>,
    handle: Option<JoinHandle<()>>,
    panics: Arc<AtomicU64>,
    expired: Arc<AtomicU64>,
}

impl<T: Send + 'static> Batcher<T> {
    pub fn new(
        batch_max: usize,
        deadline: Duration,
        process: impl FnMut(Vec<T>) + Send + 'static,
    ) -> Self {
        Self::build(batch_max, deadline, None, process)
    }

    /// Like `new`, but items that have already waited longer than
    /// `budget` when their batch flushes are dropped (and counted in
    /// `expired_dropped`) instead of processed.
    pub fn with_budget(
        batch_max: usize,
        deadline: Duration,
        budget: Duration,
        process: impl FnMut(Vec<T>) + Send + 'static,
    ) -> Self {
        Self::build(batch_max, deadline, Some(budget), process)
    }

    fn build(
        batch_max: usize,
        deadline: Duration,
        budget: Option<Duration>,
        process: impl FnMut(Vec<T>) + Send + 'static,
    ) -> Self {
        assert!(batch_max > 0);
        let (tx, rx) = channel::<(Instant, T)>();
        let mut process = process;
        let panics = Arc::new(AtomicU64::new(0));
        let panics2 = Arc::clone(&panics);
        let expired = Arc::new(AtomicU64::new(0));
        let expired2 = Arc::clone(&expired);
        let handle = std::thread::Builder::new()
            .name("asnn-batcher".into())
            .spawn(move || {
                // isolate process() panics: drop the poisoned batch,
                // keep the batcher thread (and Drop's join) alive.
                // Before processing, evict items whose budget elapsed
                // while they sat in the channel or the forming batch.
                let mut run = move |batch: Vec<(Instant, T)>| {
                    let now = Instant::now();
                    let mut items = Vec::with_capacity(batch.len());
                    for (enqueued, item) in batch {
                        match budget {
                            Some(b) if now.duration_since(enqueued) > b => {
                                expired2.fetch_add(1, Ordering::Relaxed);
                            }
                            _ => items.push(item),
                        }
                    }
                    if items.is_empty() {
                        return;
                    }
                    if catch_unwind(AssertUnwindSafe(|| process(items))).is_err() {
                        panics2.fetch_add(1, Ordering::Relaxed);
                    }
                };
                loop {
                    // block for the first item of a batch
                    let first = match rx.recv() {
                        Ok(item) => item,
                        Err(_) => break, // senders gone: shutdown
                    };
                    // deadline counts from when the first item was
                    // *submitted*, not when this thread picked it up
                    let flush_at = first.0 + deadline;
                    let mut batch = vec![first];
                    while batch.len() < batch_max {
                        let now = Instant::now();
                        if now >= flush_at {
                            break;
                        }
                        match rx.recv_timeout(flush_at - now) {
                            Ok(item) => batch.push(item),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => {
                                run(batch);
                                return;
                            }
                        }
                    }
                    run(batch);
                }
            })
            .expect("spawn batcher");
        Self { tx: Some(tx), handle: Some(handle), panics, expired }
    }

    /// Submit one item (stamped now, for deadline and budget
    /// accounting); returns false if the batcher has shut down.
    pub fn submit(&self, item: T) -> bool {
        match &self.tx {
            Some(tx) => tx.send((Instant::now(), item)).is_ok(),
            None => false,
        }
    }

    /// Batches lost to a panicking `process` closure.
    pub fn panics_caught(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    /// Items dropped because they outlived their budget before their
    /// batch flushed.
    pub fn expired_dropped(&self) -> u64 {
        self.expired.load(Ordering::Relaxed)
    }
}

impl<T: Send + 'static> Drop for Batcher<T> {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    fn collect_batches(
        batch_max: usize,
        deadline_ms: u64,
    ) -> (Batcher<u32>, Arc<Mutex<Vec<Vec<u32>>>>) {
        let sink: Arc<Mutex<Vec<Vec<u32>>>> = Arc::new(Mutex::new(Vec::new()));
        let s = Arc::clone(&sink);
        let b = Batcher::new(batch_max, Duration::from_millis(deadline_ms), move |batch| {
            s.lock().unwrap().push(batch);
        });
        (b, sink)
    }

    #[test]
    fn all_items_processed_exactly_once() {
        let (b, sink) = collect_batches(8, 5);
        for i in 0..100 {
            assert!(b.submit(i));
        }
        drop(b);
        let batches = sink.lock().unwrap();
        let mut all: Vec<u32> = batches.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn batches_respect_max() {
        let (b, sink) = collect_batches(4, 50);
        for i in 0..20 {
            b.submit(i);
        }
        drop(b);
        for batch in sink.lock().unwrap().iter() {
            assert!(batch.len() <= 4);
        }
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (b, sink) = collect_batches(1000, 20);
        b.submit(1);
        b.submit(2);
        std::thread::sleep(Duration::from_millis(120));
        {
            let batches = sink.lock().unwrap();
            assert_eq!(batches.len(), 1, "deadline flush missing: {batches:?}");
            assert_eq!(batches[0], vec![1, 2]);
        }
        drop(b);
    }

    #[test]
    fn deadline_counts_channel_queue_time() {
        // item 1 ages in the channel while process() stalls on batch 0;
        // when the batcher finally picks it up its deadline has already
        // passed, so it must flush immediately instead of granting
        // itself a fresh full deadline after pickup
        let times: Arc<Mutex<Vec<(Vec<u32>, Instant)>>> = Arc::new(Mutex::new(Vec::new()));
        let t = Arc::clone(&times);
        let b = Batcher::new(1000, Duration::from_millis(200), move |batch: Vec<u32>| {
            let stall = batch.contains(&0);
            t.lock().unwrap().push((batch, Instant::now()));
            if stall {
                std::thread::sleep(Duration::from_millis(400));
            }
        });
        b.submit(0); // flushes alone at ~200ms, then stalls until ~600ms
        std::thread::sleep(Duration::from_millis(300));
        b.submit(1); // enqueued at ~300ms; its deadline passes at ~500ms
        std::thread::sleep(Duration::from_millis(450));
        let recorded = times.lock().unwrap();
        assert_eq!(recorded.len(), 2, "got {} batches", recorded.len());
        assert_eq!(recorded[1].0, vec![1]);
        // flush 2 lands when the stall ends (~400ms after flush 1); a
        // batcher that restarted the deadline at pickup would add a
        // fresh 200ms on top
        let gap = recorded[1].1.duration_since(recorded[0].1);
        assert!(gap < Duration::from_millis(500), "{gap:?}");
        drop(recorded);
        drop(b);
    }

    #[test]
    fn budget_expired_items_are_dropped_and_counted() {
        let sink: Arc<Mutex<Vec<u32>>> = Arc::new(Mutex::new(Vec::new()));
        let s = Arc::clone(&sink);
        // slow process stalls the batcher so later items overstay their
        // 50ms budget while queued
        let b = Batcher::with_budget(
            1,
            Duration::from_millis(5),
            Duration::from_millis(50),
            move |batch: Vec<u32>| {
                if batch.contains(&0) {
                    std::thread::sleep(Duration::from_millis(120));
                }
                s.lock().unwrap().extend(batch);
            },
        );
        b.submit(0); // picked up immediately, stalls the thread
        std::thread::sleep(Duration::from_millis(20));
        b.submit(1); // waits ~100ms in the channel: expired at flush
        std::thread::sleep(Duration::from_millis(200));
        b.submit(2); // fresh: processed normally
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(b.expired_dropped(), 1);
        let got = sink.lock().unwrap().clone();
        assert_eq!(got, vec![0, 2], "expired item leaked into a batch");
        drop(b);
    }

    #[test]
    fn submit_after_drop_reports_false() {
        let (b, _sink) = collect_batches(4, 5);
        drop(b);
        // can't call submit on a dropped value; instead verify a fresh
        // batcher whose thread exited: simulate via closed channel
        let (tx, _) = std::sync::mpsc::channel::<u32>();
        drop(tx);
        // nothing to assert beyond the drop path not hanging
    }

    #[test]
    fn in_flight_items_flushed_exactly_once_when_senders_drop() {
        // items still queued at drop time must be flushed exactly once
        // (no loss, no duplication) before the Drop join returns
        let (b, sink) = collect_batches(7, 500);
        for i in 0..50 {
            assert!(b.submit(i));
        }
        drop(b); // long deadline: most items are in flight right now
        let batches = sink.lock().unwrap();
        let mut all: Vec<u32> = batches.iter().flatten().copied().collect();
        all.sort();
        all.dedup();
        assert_eq!(all, (0..50).collect::<Vec<_>>(), "lost or duplicated items");
        let total: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(total, 50, "some item was delivered twice");
    }

    #[test]
    fn panicking_process_does_not_wedge_drop() {
        let sink: Arc<Mutex<Vec<u32>>> = Arc::new(Mutex::new(Vec::new()));
        let s = Arc::clone(&sink);
        let b = Batcher::new(1, Duration::from_millis(5), move |batch: Vec<u32>| {
            if batch.contains(&13) {
                panic!("poisoned batch");
            }
            s.lock().unwrap().extend(batch);
        });
        for i in [1u32, 13, 2] {
            assert!(b.submit(i));
        }
        // wait for the poisoned batch to be consumed, then keep going
        std::thread::sleep(Duration::from_millis(100));
        assert!(b.submit(3), "batcher died after a process panic");
        let panics = b.panics_caught();
        drop(b); // must join, not wedge
        assert_eq!(panics, 1);
        let mut got = sink.lock().unwrap().clone();
        got.sort();
        assert_eq!(got, vec![1, 2, 3], "post-panic batches were lost");
    }
}
