//! Deadline batcher: groups individually-submitted items into batches
//! of at most `batch_max`, flushing when full or when the oldest item
//! has waited `deadline`.
//!
//! The coordinator uses this to feed same-window-scale queries into the
//! `disk_count_w*_b16` PJRT artifacts — the paper's serial loop,
//! vectorized across concurrent clients.
//!
//! A `process` closure that panics is caught and counted: the batch is
//! lost but the batcher thread survives, later batches still flush,
//! and `Drop` joins cleanly instead of wedging.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Generic deadline batcher; `process` receives each flushed batch on a
/// dedicated thread.
pub struct Batcher<T: Send + 'static> {
    tx: Option<Sender<T>>,
    handle: Option<JoinHandle<()>>,
    panics: Arc<AtomicU64>,
}

impl<T: Send + 'static> Batcher<T> {
    pub fn new(
        batch_max: usize,
        deadline: Duration,
        process: impl FnMut(Vec<T>) + Send + 'static,
    ) -> Self {
        assert!(batch_max > 0);
        let (tx, rx) = channel::<T>();
        let mut process = process;
        let panics = Arc::new(AtomicU64::new(0));
        let panics2 = Arc::clone(&panics);
        let handle = std::thread::Builder::new()
            .name("asnn-batcher".into())
            .spawn(move || {
                // isolate process() panics: drop the poisoned batch,
                // keep the batcher thread (and Drop's join) alive
                let mut run = move |batch: Vec<T>| {
                    if catch_unwind(AssertUnwindSafe(|| process(batch))).is_err() {
                        panics2.fetch_add(1, Ordering::Relaxed);
                    }
                };
                loop {
                    // block for the first item of a batch
                    let first = match rx.recv() {
                        Ok(item) => item,
                        Err(_) => break, // senders gone: shutdown
                    };
                    let mut batch = vec![first];
                    let flush_at = Instant::now() + deadline;
                    while batch.len() < batch_max {
                        let now = Instant::now();
                        if now >= flush_at {
                            break;
                        }
                        match rx.recv_timeout(flush_at - now) {
                            Ok(item) => batch.push(item),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => {
                                run(batch);
                                return;
                            }
                        }
                    }
                    run(batch);
                }
            })
            .expect("spawn batcher");
        Self { tx: Some(tx), handle: Some(handle), panics }
    }

    /// Submit one item; returns false if the batcher has shut down.
    pub fn submit(&self, item: T) -> bool {
        match &self.tx {
            Some(tx) => tx.send(item).is_ok(),
            None => false,
        }
    }

    /// Batches lost to a panicking `process` closure.
    pub fn panics_caught(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }
}

impl<T: Send + 'static> Drop for Batcher<T> {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    fn collect_batches(
        batch_max: usize,
        deadline_ms: u64,
    ) -> (Batcher<u32>, Arc<Mutex<Vec<Vec<u32>>>>) {
        let sink: Arc<Mutex<Vec<Vec<u32>>>> = Arc::new(Mutex::new(Vec::new()));
        let s = Arc::clone(&sink);
        let b = Batcher::new(batch_max, Duration::from_millis(deadline_ms), move |batch| {
            s.lock().unwrap().push(batch);
        });
        (b, sink)
    }

    #[test]
    fn all_items_processed_exactly_once() {
        let (b, sink) = collect_batches(8, 5);
        for i in 0..100 {
            assert!(b.submit(i));
        }
        drop(b);
        let batches = sink.lock().unwrap();
        let mut all: Vec<u32> = batches.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn batches_respect_max() {
        let (b, sink) = collect_batches(4, 50);
        for i in 0..20 {
            b.submit(i);
        }
        drop(b);
        for batch in sink.lock().unwrap().iter() {
            assert!(batch.len() <= 4);
        }
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (b, sink) = collect_batches(1000, 20);
        b.submit(1);
        b.submit(2);
        std::thread::sleep(Duration::from_millis(120));
        {
            let batches = sink.lock().unwrap();
            assert_eq!(batches.len(), 1, "deadline flush missing: {batches:?}");
            assert_eq!(batches[0], vec![1, 2]);
        }
        drop(b);
    }

    #[test]
    fn submit_after_drop_reports_false() {
        let (b, _sink) = collect_batches(4, 5);
        drop(b);
        // can't call submit on a dropped value; instead verify a fresh
        // batcher whose thread exited: simulate via closed channel
        let (tx, _) = std::sync::mpsc::channel::<u32>();
        drop(tx);
        // nothing to assert beyond the drop path not hanging
    }

    #[test]
    fn in_flight_items_flushed_exactly_once_when_senders_drop() {
        // items still queued at drop time must be flushed exactly once
        // (no loss, no duplication) before the Drop join returns
        let (b, sink) = collect_batches(7, 500);
        for i in 0..50 {
            assert!(b.submit(i));
        }
        drop(b); // long deadline: most items are in flight right now
        let batches = sink.lock().unwrap();
        let mut all: Vec<u32> = batches.iter().flatten().copied().collect();
        all.sort();
        all.dedup();
        assert_eq!(all, (0..50).collect::<Vec<_>>(), "lost or duplicated items");
        let total: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(total, 50, "some item was delivered twice");
    }

    #[test]
    fn panicking_process_does_not_wedge_drop() {
        let sink: Arc<Mutex<Vec<u32>>> = Arc::new(Mutex::new(Vec::new()));
        let s = Arc::clone(&sink);
        let b = Batcher::new(1, Duration::from_millis(5), move |batch: Vec<u32>| {
            if batch.contains(&13) {
                panic!("poisoned batch");
            }
            s.lock().unwrap().extend(batch);
        });
        for i in [1u32, 13, 2] {
            assert!(b.submit(i));
        }
        // wait for the poisoned batch to be consumed, then keep going
        std::thread::sleep(Duration::from_millis(100));
        assert!(b.submit(3), "batcher died after a process panic");
        let panics = b.panics_caught();
        drop(b); // must join, not wedge
        assert_eq!(panics, 1);
        let mut got = sink.lock().unwrap().clone();
        got.sort();
        assert_eq!(got, vec![1, 2, 3], "post-panic batches were lost");
    }
}
