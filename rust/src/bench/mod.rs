//! In-repo benchmark harness (criterion is not in the offline vendor
//! set): warmup + timed iterations, robust stats, and aligned table /
//! CSV output so every paper figure can be regenerated as text series.

pub mod harness;

pub use harness::{run, BenchResult, BenchSpec, Table};
