//! Measurement core: warmup, adaptive iteration count, percentile
//! stats, and table output.

use crate::util::stats::{percentile, Welford};
use crate::util::timer::{fmt_duration, Timer};

/// What to measure and for how long.
#[derive(Debug, Clone)]
pub struct BenchSpec {
    pub name: String,
    /// Warmup wall-time budget (seconds).
    pub warmup_secs: f64,
    /// Measurement wall-time budget (seconds).
    pub measure_secs: f64,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
    /// Minimum measured iterations (even past the time budget).
    pub min_iters: usize,
}

impl BenchSpec {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            warmup_secs: 0.2,
            measure_secs: 1.0,
            max_iters: 10_000,
            min_iters: 5,
        }
    }

    /// Faster profile for long-running end-to-end benches.
    pub fn quick(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            warmup_secs: 0.05,
            measure_secs: 0.3,
            max_iters: 1_000,
            min_iters: 3,
        }
    }
}

/// Aggregated measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>10} {:>10} {:>10} {:>10} {:>6}",
            self.name,
            fmt_duration(self.mean_secs),
            fmt_duration(self.p50_secs),
            fmt_duration(self.p99_secs),
            fmt_duration(self.max_secs),
            self.iters
        )
    }

    pub fn header() -> String {
        format!(
            "{:<40} {:>10} {:>10} {:>10} {:>10} {:>6}",
            "benchmark", "mean", "p50", "p99", "max", "iters"
        )
    }
}

/// Run one benchmark: `f` is a single measured operation.
pub fn run(spec: &BenchSpec, mut f: impl FnMut()) -> BenchResult {
    // warmup
    let t = Timer::new();
    while t.elapsed_secs() < spec.warmup_secs {
        f();
    }
    // measure
    let mut samples = Vec::new();
    let mut w = Welford::new();
    let total = Timer::new();
    while (total.elapsed_secs() < spec.measure_secs || samples.len() < spec.min_iters)
        && samples.len() < spec.max_iters
    {
        let it = Timer::new();
        f();
        let s = it.elapsed_secs();
        samples.push(s);
        w.push(s);
    }
    BenchResult {
        name: spec.name.clone(),
        iters: samples.len(),
        mean_secs: w.mean(),
        std_secs: w.std(),
        p50_secs: percentile(&mut samples.clone(), 50.0),
        p99_secs: percentile(&mut samples, 99.0),
        min_secs: w.min(),
        max_secs: w.max(),
    }
}

/// Aligned text table that doubles as CSV (for EXPERIMENTS.md series).
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, columns: &[&str]) -> Self {
        Self {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Aligned human-readable rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Machine-readable CSV rendering.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.columns.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Print both renderings (csv fenced for easy scraping).
    pub fn print(&self) {
        println!("{}", self.render());
        println!("csv:{}", self.title.replace(' ', "_"));
        print!("{}", self.to_csv());
        println!("endcsv");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_measures_positive_times() {
        let spec = BenchSpec {
            name: "noop".into(),
            warmup_secs: 0.0,
            measure_secs: 0.01,
            max_iters: 100,
            min_iters: 5,
        };
        let mut count = 0u64;
        let r = run(&spec, || {
            count = count.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 5);
        assert!(r.mean_secs >= 0.0);
        assert!(r.p50_secs <= r.p99_secs + 1e-12);
        assert!(r.min_secs <= r.max_secs);
    }

    #[test]
    fn run_respects_max_iters() {
        let spec = BenchSpec {
            name: "capped".into(),
            warmup_secs: 0.0,
            measure_secs: 10.0,
            max_iters: 7,
            min_iters: 1,
        };
        let r = run(&spec, || {});
        assert_eq!(r.iters, 7);
    }

    #[test]
    fn table_renders_aligned_and_csv() {
        let mut t = Table::new("fig3", &["n", "engine", "secs"]);
        t.row(&["1000".into(), "brute".into(), "0.5".into()]);
        t.row(&["100000".into(), "active".into(), "0.002".into()]);
        let rendered = t.render();
        assert!(rendered.contains("fig3"));
        assert!(rendered.contains("100000"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("n,engine,secs"));
        assert_eq!(t.num_rows(), 2);
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }
}
