//! Minimal JSON value type, renderer, and parser.
//!
//! serde is not in the offline vendor set, so the telemetry wire API
//! (`STATS2 json`, `TRACE`) hand-rolls its JSON. The surface is small
//! and deliberately strict:
//!
//! - [`Json`] is the value tree; objects keep insertion order so every
//!   render is deterministic.
//! - [`Json::render`] emits one line (no interior newlines — the wire
//!   protocol folds newlines), integers as integers, and other finite
//!   floats via Rust's shortest-round-trip `Display`, so
//!   `parse(render(v)) == v` holds for everything the telemetry layer
//!   produces. Non-finite floats render as `null`.
//! - [`Json::parse`] is a recursive-descent parser with a depth cap,
//!   used by the round-trip tests and by snapshot restore.

use crate::error::{AsnnError, Result};

/// Largest integer exactly representable in an `f64`.
const MAX_SAFE_INT: f64 = 9_007_199_254_740_992.0; // 2^53

/// Nesting depth cap for the parser (hostile input guard).
const MAX_DEPTH: usize = 64;

/// A JSON value. Objects preserve insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience constructor for object values.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Convenience constructor for unsigned counters.
    pub fn num_u64(v: u64) -> Json {
        Json::Num(v as f64)
    }

    /// Field lookup on an object; `None` for other variants.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= MAX_SAFE_INT => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Render to a single-line JSON string.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(64);
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => render_num(*n, out),
            Json::Str(s) => render_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_str(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Trailing garbage is an error.
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(err(pos, "trailing characters after document"));
        }
        Ok(value)
    }
}

fn render_num(n: f64, out: &mut String) {
    use std::fmt::Write;
    if !n.is_finite() {
        // JSON has no NaN/Inf; telemetry guards against producing them,
        // but render defensively rather than emitting invalid output.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() <= MAX_SAFE_INT {
        let _ = write!(out, "{}", n as i64);
    } else {
        // Rust's f64 Display is shortest-round-trip, so parse(render(n))
        // recovers the exact value.
        let _ = write!(out, "{n}");
    }
}

fn render_str(s: &str, out: &mut String) {
    use std::fmt::Write;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn err(pos: usize, what: &str) -> AsnnError {
    AsnnError::Protocol(format!("json at byte {pos}: {what}"))
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, b: u8) -> Result<()> {
    if *pos < bytes.len() && bytes[*pos] == b {
        *pos += 1;
        Ok(())
    } else {
        Err(err(*pos, "unexpected character"))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    if depth > MAX_DEPTH {
        return Err(err(*pos, "nesting too deep"));
    }
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err(err(*pos, "unexpected end of input")),
        Some(b'{') => parse_obj(bytes, pos, depth),
        Some(b'[') => parse_arr(bytes, pos, depth),
        Some(b'"') => Ok(Json::Str(parse_str(bytes, pos)?)),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        Some(_) => parse_num(bytes, pos),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(err(*pos, "invalid literal"))
    }
}

fn parse_num(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if bytes.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let token = std::str::from_utf8(&bytes[start..*pos]).map_err(|_| err(start, "bad number"))?;
    let n: f64 = token.parse().map_err(|_| err(start, "bad number"))?;
    if !n.is_finite() {
        return Err(err(start, "number out of range"));
    }
    Ok(Json::Num(n))
}

fn parse_str(bytes: &[u8], pos: &mut usize) -> Result<String> {
    expect(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err(err(*pos, "unterminated string")),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        *pos += 1;
                        let cp = parse_hex4(bytes, pos)?;
                        let c = if (0xD800..=0xDBFF).contains(&cp) {
                            // high surrogate: require the paired \uXXXX
                            if bytes.get(*pos) != Some(&b'\\') || bytes.get(*pos + 1) != Some(&b'u')
                            {
                                return Err(err(*pos, "lone surrogate"));
                            }
                            *pos += 2;
                            let lo = parse_hex4(bytes, pos)?;
                            if !(0xDC00..=0xDFFF).contains(&lo) {
                                return Err(err(*pos, "invalid surrogate pair"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined).ok_or_else(|| err(*pos, "bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| err(*pos, "bad codepoint"))?
                        };
                        out.push(c);
                        continue; // pos already past the escape
                    }
                    _ => return Err(err(*pos, "bad escape")),
                }
                *pos += 1;
            }
            Some(&b) if b < 0x20 => return Err(err(*pos, "raw control character")),
            Some(_) => {
                // copy one UTF-8 character (1–4 bytes)
                let rest = std::str::from_utf8(&bytes[*pos..])
                    .map_err(|_| err(*pos, "invalid utf-8"))?;
                let c = rest.chars().next().ok_or_else(|| err(*pos, "unterminated string"))?;
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_hex4(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    if *pos + 4 > bytes.len() {
        return Err(err(*pos, "short \\u escape"));
    }
    let token =
        std::str::from_utf8(&bytes[*pos..*pos + 4]).map_err(|_| err(*pos, "bad \\u escape"))?;
    let cp = u32::from_str_radix(token, 16).map_err(|_| err(*pos, "bad \\u escape"))?;
    *pos += 4;
    Ok(cp)
}

fn parse_arr(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    expect(bytes, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos, depth + 1)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(err(*pos, "expected ',' or ']'")),
        }
    }
}

fn parse_obj(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    expect(bytes, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(bytes, pos);
        let key = parse_str(bytes, pos)?;
        skip_ws(bytes, pos);
        expect(bytes, pos, b':')?;
        let value = parse_value(bytes, pos, depth + 1)?;
        fields.push((key, value));
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => {
                *pos += 1;
            }
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => return Err(err(*pos, "expected ',' or '}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrips() {
        for v in [
            Json::Null,
            Json::Bool(true),
            Json::Bool(false),
            Json::Num(0.0),
            Json::Num(-17.0),
            Json::Num(3.25),
            Json::Num(1e-9),
            Json::num_u64(u64::MAX >> 12),
            Json::Str(String::new()),
            Json::Str("hello \"world\"\n\t\\".into()),
            Json::Str("unicode: éλ🦀".into()),
        ] {
            let rendered = v.render();
            assert_eq!(Json::parse(&rendered).unwrap(), v, "text: {rendered}");
        }
    }

    #[test]
    fn containers_roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5), Json::Null])),
            ("b", Json::obj(vec![("nested", Json::Bool(true))])),
            ("c", Json::Str("x".into())),
        ]);
        let rendered = v.render();
        assert_eq!(Json::parse(&rendered).unwrap(), v);
        // parse → render is also stable
        assert_eq!(Json::parse(&rendered).unwrap().render(), rendered);
    }

    #[test]
    fn integers_render_without_exponent() {
        assert_eq!(Json::num_u64(1_000_000_000_000).render(), "1000000000000");
        assert_eq!(Json::Num(-42.0).render(), "-42");
    }

    #[test]
    fn object_order_is_preserved() {
        let v = Json::obj(vec![("z", Json::Num(1.0)), ("a", Json::Num(2.0))]);
        assert_eq!(v.render(), "{\"z\":1,\"a\":2}");
    }

    #[test]
    fn parses_foreign_whitespace_and_escapes() {
        let v = Json::parse(" { \"k\" : [ 1 , \"\\u0041\\ud83e\\udd80\" ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap()[1].as_str().unwrap(), "A🦀");
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"k\":}", "tru", "\"unterminated", "1 2", "{\"a\":1}x",
            "\"\\q\"", "\"\\ud800\"", "nan",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn rejects_deep_nesting() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn non_finite_renders_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn as_u64_guards() {
        assert_eq!(Json::Num(5.0).as_u64(), Some(5));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }
}
