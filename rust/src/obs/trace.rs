//! Stable, serializable trace records.
//!
//! [`SearchTrace`] began life as an ad-hoc debug struct inside
//! `active/`; it is now the crate-wide trace record every
//! [`crate::engine::NnEngine`] populates (via `knn_trace`), carrying
//! both the paper-level radius schedule ([`SearchStep`]) and wall-clock
//! [`StageSpan`]s. [`QueryTrace`] wraps one traced request end-to-end
//! and renders the span tree returned by the `TRACE` wire verb.
//!
//! The JSON schema is documented in `docs/OBSERVABILITY.md`; treat
//! field names here as a wire contract.

use super::json::Json;

/// A pipeline stage with its own latency histogram and trace spans.
///
/// `Coarse`/`Refine`/`Scan` are the paper's staged search (radius
/// iteration, candidate re-rank, disk collection); `Retry`, `Hedge`,
/// and `BatchWait` are coordinator stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Stage {
    Coarse = 0,
    Refine = 1,
    Scan = 2,
    Retry = 3,
    Hedge = 4,
    BatchWait = 5,
}

impl Stage {
    /// Every stage, in histogram index order.
    pub const ALL: [Stage; 6] =
        [Stage::Coarse, Stage::Refine, Stage::Scan, Stage::Retry, Stage::Hedge, Stage::BatchWait];

    /// Stable wire name (used in `STATS2` keys and trace span names).
    pub fn as_str(&self) -> &'static str {
        match self {
            Stage::Coarse => "coarse",
            Stage::Refine => "refine",
            Stage::Scan => "scan",
            Stage::Retry => "retry",
            Stage::Hedge => "hedge",
            Stage::BatchWait => "batch_wait",
        }
    }

    pub fn parse(s: &str) -> Option<Stage> {
        Stage::ALL.into_iter().find(|st| st.as_str() == s)
    }
}

/// One timed span attributed to a [`Stage`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageSpan {
    pub stage: Stage,
    pub dur_ns: u64,
}

/// One step of an active search, recorded for traces and Fig. 2.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchStep {
    /// Radius used this iteration (pixels).
    pub r: u32,
    /// Points counted inside the circle.
    pub n: u64,
}

/// Full trace of one engine-level search: the paper's radius schedule
/// plus wall-clock spans per stage. Every engine populates this (see
/// `NnEngine::knn_trace`); engines without a staged pipeline report a
/// single `scan` span covering the whole query.
#[derive(Debug, Clone, Default)]
pub struct SearchTrace {
    pub steps: Vec<SearchStep>,
    /// True if the loop ended by |n−k| ≤ tolerance, false if it hit the
    /// max-iteration guard or the radius cap.
    pub converged: bool,
    /// Radius growth steps resolved from pyramid upper bounds alone —
    /// coarse-to-fine skips that never paid for an exact disk scan, so
    /// they appear in neither `steps` nor the work accounting.
    pub coarse_skips: u32,
    /// Wall-clock spans, one per stage the query passed through.
    pub spans: Vec<StageSpan>,
}

impl SearchTrace {
    pub fn iterations(&self) -> usize {
        self.steps.len()
    }

    pub fn final_radius(&self) -> Option<u32> {
        self.steps.last().map(|s| s.r)
    }

    /// Append a stage span (merges into an existing span for the same
    /// stage so repeated scan rounds aggregate).
    pub fn push_span(&mut self, stage: Stage, dur_ns: u64) {
        if let Some(span) = self.spans.iter_mut().find(|s| s.stage == stage) {
            span.dur_ns += dur_ns;
        } else {
            self.spans.push(StageSpan { stage, dur_ns });
        }
    }

    /// Total nanoseconds attributed to `stage` (0 if absent).
    pub fn span_ns(&self, stage: Stage) -> u64 {
        self.spans.iter().filter(|s| s.stage == stage).map(|s| s.dur_ns).sum()
    }

    /// Sum of all stage spans.
    pub fn spans_total_ns(&self) -> u64 {
        self.spans.iter().map(|s| s.dur_ns).sum()
    }
}

/// One traced request end-to-end: what the `TRACE` verb returns.
#[derive(Debug, Clone)]
pub struct QueryTrace {
    /// Engine that served the query.
    pub engine: String,
    pub k: usize,
    pub query: Vec<f64>,
    /// Wall-clock time inside the engine call.
    pub engine_ns: u64,
    /// Wall-clock time for the whole request as seen by the router.
    pub total_ns: u64,
    /// Neighbors returned (count only is serialized).
    pub neighbors: usize,
    pub search: SearchTrace,
}

impl QueryTrace {
    /// Render the span tree: `request` → `engine:<name>` → stage spans.
    /// Stage spans are disjoint sub-intervals of the engine call, so
    /// their durations sum to ≤ `engine_ns` ≤ `total_ns` — the
    /// invariant the e2e suite checks.
    pub fn to_json(&self) -> Json {
        let stage_spans: Vec<Json> = self
            .search
            .spans
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::Str(s.stage.as_str().into())),
                    ("dur_ns", Json::num_u64(s.dur_ns)),
                ])
            })
            .collect();
        let engine_span = Json::obj(vec![
            ("name", Json::Str(format!("engine:{}", self.engine))),
            ("dur_ns", Json::num_u64(self.engine_ns)),
            ("children", Json::Arr(stage_spans)),
        ]);
        let root = Json::obj(vec![
            ("name", Json::Str("request".into())),
            ("dur_ns", Json::num_u64(self.total_ns)),
            ("children", Json::Arr(vec![engine_span])),
        ]);
        let steps: Vec<Json> = self
            .search
            .steps
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("r", Json::num_u64(u64::from(s.r))),
                    ("n", Json::num_u64(s.n)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("v", Json::Num(1.0)),
            ("engine", Json::Str(self.engine.clone())),
            ("k", Json::num_u64(self.k as u64)),
            ("query", Json::Arr(self.query.iter().map(|&c| Json::Num(c)).collect())),
            ("neighbors", Json::num_u64(self.neighbors as u64)),
            ("total_ns", Json::num_u64(self.total_ns)),
            ("converged", Json::Bool(self.search.converged)),
            ("iterations", Json::num_u64(self.search.iterations() as u64)),
            ("coarse_skips", Json::num_u64(u64::from(self.search.coarse_skips))),
            ("steps", Json::Arr(steps)),
            ("root", root),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_roundtrip() {
        for stage in Stage::ALL {
            assert_eq!(Stage::parse(stage.as_str()), Some(stage));
        }
        assert_eq!(Stage::parse("bogus"), None);
    }

    #[test]
    fn push_span_merges_same_stage() {
        let mut t = SearchTrace::default();
        t.push_span(Stage::Scan, 10);
        t.push_span(Stage::Coarse, 5);
        t.push_span(Stage::Scan, 7);
        assert_eq!(t.spans.len(), 2);
        assert_eq!(t.span_ns(Stage::Scan), 17);
        assert_eq!(t.spans_total_ns(), 22);
    }

    #[test]
    fn trace_json_has_span_tree() {
        let mut search = SearchTrace { converged: true, ..Default::default() };
        search.steps.push(SearchStep { r: 100, n: 7 });
        search.push_span(Stage::Coarse, 300);
        search.push_span(Stage::Scan, 500);
        let trace = QueryTrace {
            engine: "active".into(),
            k: 3,
            query: vec![0.25, 0.75],
            engine_ns: 900,
            total_ns: 1200,
            neighbors: 3,
            search,
        };
        let doc = trace.to_json();
        let root = doc.get("root").unwrap();
        assert_eq!(root.get("dur_ns").unwrap().as_u64(), Some(1200));
        let engine = &root.get("children").unwrap().as_arr().unwrap()[0];
        assert_eq!(engine.get("name").unwrap().as_str(), Some("engine:active"));
        let leaves = engine.get("children").unwrap().as_arr().unwrap();
        let leaf_sum: u64 = leaves.iter().map(|l| l.get("dur_ns").unwrap().as_u64().unwrap()).sum();
        assert!(leaf_sum <= trace.engine_ns);
        // and the rendered document survives a parse
        let parsed = Json::parse(&doc.render()).unwrap();
        assert_eq!(parsed, doc);
    }
}
