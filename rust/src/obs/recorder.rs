//! Lock-light telemetry recorder.
//!
//! One [`Recorder`] lives behind the router (shared `Arc`) and is fed
//! from every layer: engines record coarse/refine/scan stage times,
//! the router records retry and hedge waits plus per-engine outcomes,
//! the batching lane records queue waits. Recording is wait-free
//! (relaxed atomics); the only lock is a briefly-held `RwLock` on the
//! per-engine registry, taken in write mode once per engine lifetime.
//!
//! [`ObsSnapshot`] is the read-side view: it renders `STATS2` sections
//! and the `obs` generation files persisted by the snapshotter, and
//! restores across restarts via [`Recorder::restore`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use super::hist::{AtomicHistogram, HistSnapshot};
use super::json::Json;
use super::trace::Stage;
use crate::error::{AsnnError, Result};

/// Wait-free per-engine counters plus a latency histogram.
#[derive(Debug, Default)]
pub struct EngineCounters {
    /// Attempts settled against this engine (success + failure).
    pub requests: AtomicU64,
    /// Failed attempts.
    pub errors: AtomicU64,
    /// Individual queries served through the batched path.
    pub batch_queries: AtomicU64,
    /// Per-attempt latency (successful attempts only).
    pub latency: AtomicHistogram,
}

impl EngineCounters {
    pub fn record_ok(&self, ns: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latency.record_ns(ns);
    }

    pub fn record_err(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, queries: u64) {
        self.batch_queries.fetch_add(queries, Ordering::Relaxed);
    }
}

/// The telemetry hub. Cheap to clone via `Arc`; all methods take
/// `&self`.
#[derive(Debug, Default)]
pub struct Recorder {
    stages: [AtomicHistogram; 6],
    engines: RwLock<BTreeMap<String, Arc<EngineCounters>>>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one span for `stage`. Wait-free.
    #[inline]
    pub fn record_stage(&self, stage: Stage, ns: u64) {
        self.stages[stage as usize].record_ns(ns);
    }

    /// Counters for `name`, creating them on first use. The write lock
    /// is taken only on that first use; steady state is a read lock.
    pub fn engine(&self, name: &str) -> Arc<EngineCounters> {
        if let Some(c) = self.engines.read().expect("obs registry poisoned").get(name) {
            return Arc::clone(c);
        }
        let mut map = self.engines.write().expect("obs registry poisoned");
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    pub fn record_engine_ok(&self, name: &str, ns: u64) {
        self.engine(name).record_ok(ns);
    }

    pub fn record_engine_err(&self, name: &str) {
        self.engine(name).record_err();
    }

    pub fn record_engine_batch(&self, name: &str, queries: u64) {
        self.engine(name).record_batch(queries);
    }

    /// Point-in-time copy of everything the recorder holds.
    pub fn snapshot(&self) -> ObsSnapshot {
        let stages = Stage::ALL
            .into_iter()
            .map(|s| (s, self.stages[s as usize].snapshot()))
            .collect();
        let engines = self
            .engines
            .read()
            .expect("obs registry poisoned")
            .iter()
            .map(|(name, c)| EngineSnapshot {
                name: name.clone(),
                requests: c.requests.load(Ordering::Relaxed),
                errors: c.errors.load(Ordering::Relaxed),
                batch_queries: c.batch_queries.load(Ordering::Relaxed),
                latency: c.latency.snapshot(),
            })
            .collect();
        ObsSnapshot { stages, engines }
    }

    /// Fold a persisted snapshot's counts back in (warm restart). Adds
    /// to whatever has been recorded since boot.
    pub fn restore(&self, snap: &ObsSnapshot) {
        for (stage, hist) in &snap.stages {
            self.stages[*stage as usize].add(hist);
        }
        for e in &snap.engines {
            let counters = self.engine(&e.name);
            counters.requests.fetch_add(e.requests, Ordering::Relaxed);
            counters.errors.fetch_add(e.errors, Ordering::Relaxed);
            counters.batch_queries.fetch_add(e.batch_queries, Ordering::Relaxed);
            counters.latency.add(&e.latency);
        }
    }

    /// Serialized snapshot for the crash-safe store (`obs` generation
    /// payload: the JSON document, framed/checksummed by the store).
    pub fn export_bytes(&self) -> Vec<u8> {
        self.snapshot().to_json().render().into_bytes()
    }

    /// Restore from [`export_bytes`](Self::export_bytes) output.
    pub fn restore_bytes(&self, payload: &[u8]) -> Result<()> {
        let text = std::str::from_utf8(payload)
            .map_err(|_| AsnnError::Store("obs snapshot: not utf-8".into()))?;
        let snap = ObsSnapshot::from_json(&Json::parse(text)?)?;
        self.restore(&snap);
        Ok(())
    }
}

/// Per-engine counter snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineSnapshot {
    pub name: String,
    pub requests: u64,
    pub errors: u64,
    pub batch_queries: u64,
    pub latency: HistSnapshot,
}

/// Point-in-time recorder state: stage histograms + engine counters.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsSnapshot {
    pub stages: Vec<(Stage, HistSnapshot)>,
    pub engines: Vec<EngineSnapshot>,
}

impl ObsSnapshot {
    pub fn stage(&self, stage: Stage) -> Option<&HistSnapshot> {
        self.stages.iter().find(|(s, _)| *s == stage).map(|(_, h)| h)
    }

    /// JSON export: `{"stages": {...}, "engines": {...}}`.
    pub fn to_json(&self) -> Json {
        let stages = self
            .stages
            .iter()
            .map(|(s, h)| (s.as_str().to_string(), h.to_json()))
            .collect();
        let engines = self
            .engines
            .iter()
            .map(|e| {
                (
                    e.name.clone(),
                    Json::obj(vec![
                        ("requests", Json::num_u64(e.requests)),
                        ("errors", Json::num_u64(e.errors)),
                        ("batch_queries", Json::num_u64(e.batch_queries)),
                        ("latency", e.latency.to_json()),
                    ]),
                )
            })
            .collect();
        Json::Obj(vec![
            ("stages".to_string(), Json::Obj(stages)),
            ("engines".to_string(), Json::Obj(engines)),
        ])
    }

    /// Rebuild from [`to_json`](Self::to_json) output. Unknown stage
    /// names are rejected; unknown extra fields are ignored so the
    /// schema can grow.
    pub fn from_json(v: &Json) -> Result<ObsSnapshot> {
        let stage_obj = match v.get("stages") {
            Some(Json::Obj(fields)) => fields,
            _ => return Err(AsnnError::Protocol("obs snapshot: missing stages".into())),
        };
        let mut stages = Vec::with_capacity(stage_obj.len());
        for (name, hist) in stage_obj {
            let stage = Stage::parse(name)
                .ok_or_else(|| AsnnError::Protocol(format!("obs snapshot: unknown stage {name}")))?;
            stages.push((stage, HistSnapshot::from_json(hist)?));
        }
        let engine_obj = match v.get("engines") {
            Some(Json::Obj(fields)) => fields,
            _ => return Err(AsnnError::Protocol("obs snapshot: missing engines".into())),
        };
        let mut engines = Vec::with_capacity(engine_obj.len());
        for (name, body) in engine_obj {
            let field = |key: &str| -> Result<u64> {
                body.get(key).and_then(Json::as_u64).ok_or_else(|| {
                    AsnnError::Protocol(format!("obs snapshot: engine {name} missing {key}"))
                })
            };
            engines.push(EngineSnapshot {
                name: name.clone(),
                requests: field("requests")?,
                errors: field("errors")?,
                batch_queries: field("batch_queries")?,
                latency: HistSnapshot::from_json(body.get("latency").ok_or_else(|| {
                    AsnnError::Protocol(format!("obs snapshot: engine {name} missing latency"))
                })?)?,
            });
        }
        Ok(ObsSnapshot { stages, engines })
    }

    /// Flat `key=value` rendering for `STATS2 text` (space-separated —
    /// the wire protocol keeps responses on one line).
    pub fn render_text(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        for (stage, h) in &self.stages {
            let _ = write!(
                out,
                "stage.{0}.count={1} stage.{0}.p50_us={2:.1} stage.{0}.p90_us={3:.1} \
                 stage.{0}.p99_us={4:.1} stage.{0}.mean_us={5:.1} ",
                stage.as_str(),
                h.count,
                h.quantile_ns(0.50) as f64 / 1e3,
                h.quantile_ns(0.90) as f64 / 1e3,
                h.quantile_ns(0.99) as f64 / 1e3,
                h.mean_ns() / 1e3,
            );
        }
        for e in &self.engines {
            let _ = write!(
                out,
                "engine.{0}.requests={1} engine.{0}.errors={2} engine.{0}.batched={3} \
                 engine.{0}.p99_us={4:.1} ",
                e.name,
                e.requests,
                e.errors,
                e.batch_queries,
                e.latency.quantile_ns(0.99) as f64 / 1e3,
            );
        }
        out.trim_end().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_records() {
        let r = Recorder::new();
        r.record_stage(Stage::Coarse, 1_000);
        r.record_stage(Stage::Coarse, 2_000);
        r.record_stage(Stage::Scan, 500);
        r.record_engine_ok("active", 5_000);
        r.record_engine_err("active");
        r.record_engine_batch("brute", 32);
        let snap = r.snapshot();
        assert_eq!(snap.stage(Stage::Coarse).unwrap().count, 2);
        assert_eq!(snap.stage(Stage::Scan).unwrap().count, 1);
        assert_eq!(snap.stage(Stage::Refine).unwrap().count, 0);
        let active = snap.engines.iter().find(|e| e.name == "active").unwrap();
        assert_eq!(active.requests, 2);
        assert_eq!(active.errors, 1);
        let brute = snap.engines.iter().find(|e| e.name == "brute").unwrap();
        assert_eq!(brute.batch_queries, 32);
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let r = Recorder::new();
        r.record_stage(Stage::Refine, 123);
        r.record_stage(Stage::BatchWait, 45_678);
        r.record_engine_ok("kdtree", 900);
        let snap = r.snapshot();
        let parsed = Json::parse(&snap.to_json().render()).unwrap();
        assert_eq!(ObsSnapshot::from_json(&parsed).unwrap(), snap);
    }

    #[test]
    fn restore_accumulates() {
        let a = Recorder::new();
        a.record_stage(Stage::Hedge, 10);
        a.record_engine_ok("brute", 100);
        let persisted = a.export_bytes();

        let b = Recorder::new();
        b.record_stage(Stage::Hedge, 20);
        b.restore_bytes(&persisted).unwrap();
        let snap = b.snapshot();
        assert_eq!(snap.stage(Stage::Hedge).unwrap().count, 2);
        assert_eq!(snap.engines.iter().find(|e| e.name == "brute").unwrap().requests, 1);
    }

    #[test]
    fn restore_rejects_garbage() {
        let r = Recorder::new();
        assert!(r.restore_bytes(b"not json").is_err());
        assert!(r.restore_bytes(b"{}").is_err());
        assert!(r.restore_bytes(b"{\"stages\":{\"bogus\":{}},\"engines\":{}}").is_err());
    }

    #[test]
    fn engine_registry_is_shared() {
        let r = Arc::new(Recorder::new());
        let c1 = r.engine("x");
        let c2 = r.engine("x");
        c1.record_ok(10);
        c2.record_ok(20);
        assert_eq!(r.snapshot().engines[0].requests, 2);
    }

    #[test]
    fn text_rendering_is_flat_single_line() {
        let r = Recorder::new();
        r.record_stage(Stage::Coarse, 1_000);
        r.record_engine_ok("active", 2_000);
        let text = r.snapshot().render_text();
        assert!(text.contains("stage.coarse.count=1"));
        assert!(text.contains("engine.active.requests=1"));
        assert!(!text.contains('\n'));
    }
}
