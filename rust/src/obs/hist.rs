//! Lock-free fixed-bucket latency histogram.
//!
//! Same bucketing as [`crate::util::stats::LatencyHistogram`] — 64
//! power-of-two buckets indexed by `floor(log2(ns))` — but counters are
//! relaxed atomics so the serving hot path records without taking a
//! lock (the coordinator's `Metrics` histograms sit behind a `Mutex`;
//! per-stage recording happens inside the engine's query loop where
//! that would show up).
//!
//! Quantiles are read from an immutable [`HistSnapshot`] and report the
//! bucket's upper edge, so they overestimate by at most 2×, never
//! underestimate — the same contract as the locked histogram.

use std::sync::atomic::{AtomicU64, Ordering};

use super::json::Json;
use crate::error::{AsnnError, Result};

const BUCKETS: usize = 64;

/// Bucket index for a nanosecond value: `floor(log2(ns))`, with 0 ns
/// clamped into bucket 0.
#[inline]
pub fn bucket_of(ns: u64) -> usize {
    (63 - ns.max(1).leading_zeros()) as usize
}

/// Lock-free histogram: record with relaxed atomics, read via
/// [`snapshot`](AtomicHistogram::snapshot).
#[derive(Debug)]
pub struct AtomicHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHistogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Record one sample. Wait-free; safe from any thread.
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Add a previously captured snapshot's counts (snapshot restore
    /// after a crash, or merging shards).
    pub fn add(&self, snap: &HistSnapshot) {
        for (bucket, &n) in self.buckets.iter().zip(snap.buckets.iter()) {
            if n > 0 {
                bucket.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(snap.count, Ordering::Relaxed);
        self.sum_ns.fetch_add(snap.sum_ns, Ordering::Relaxed);
        self.max_ns.fetch_max(snap.max_ns, Ordering::Relaxed);
    }

    /// Capture a point-in-time copy. Individual counters are read
    /// relaxed, so a snapshot taken mid-record can be off by the
    /// in-flight sample — fine for telemetry.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
        }
    }
}

/// Immutable histogram state: the unit of quantile math, JSON export,
/// and snapshot persistence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum_ns: u64,
    pub max_ns: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self { buckets: [0; BUCKETS], count: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl HistSnapshot {
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean in nanoseconds; 0 when empty (JSON has no NaN).
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Quantile upper bound in nanoseconds for `q ∈ [0, 1]`: the upper
    /// edge of the bucket holding the q-th sample. 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return 1u64 << (i + 1).min(63);
            }
        }
        self.max_ns
    }

    /// JSON export: summary quantiles plus the sparse bucket vector
    /// (`[[index, count], ...]`) so snapshots restore losslessly without
    /// shipping 64 mostly-zero entries per histogram.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, &n)| Json::Arr(vec![Json::num_u64(i as u64), Json::num_u64(n)]))
            .collect();
        Json::obj(vec![
            ("count", Json::num_u64(self.count)),
            ("sum_ns", Json::num_u64(self.sum_ns)),
            ("max_ns", Json::num_u64(self.max_ns)),
            ("mean_ns", Json::Num(self.mean_ns())),
            ("p50_ns", Json::num_u64(self.quantile_ns(0.50))),
            ("p90_ns", Json::num_u64(self.quantile_ns(0.90))),
            ("p99_ns", Json::num_u64(self.quantile_ns(0.99))),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    /// Rebuild from [`to_json`](Self::to_json) output. Derived fields
    /// (mean, quantiles) are recomputed, not trusted.
    pub fn from_json(v: &Json) -> Result<HistSnapshot> {
        let field = |key: &str| -> Result<u64> {
            v.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| AsnnError::Protocol(format!("histogram: missing field {key}")))
        };
        let mut snap = HistSnapshot {
            count: field("count")?,
            sum_ns: field("sum_ns")?,
            max_ns: field("max_ns")?,
            ..HistSnapshot::default()
        };
        let buckets = v
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| AsnnError::Protocol("histogram: missing buckets".into()))?;
        for entry in buckets {
            let pair = entry
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| AsnnError::Protocol("histogram: bad bucket entry".into()))?;
            let (i, n) = (pair[0].as_u64(), pair[1].as_u64());
            match (i, n) {
                (Some(i), Some(n)) if (i as usize) < BUCKETS => snap.buckets[i as usize] = n,
                _ => return Err(AsnnError::Protocol("histogram: bad bucket entry".into())),
            }
        }
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketing_matches_locked_histogram() {
        use crate::util::stats::LatencyHistogram;
        let atomic = AtomicHistogram::new();
        let mut locked = LatencyHistogram::new();
        for ns in [0, 1, 2, 3, 1000, 1_000_000, u64::MAX] {
            atomic.record_ns(ns);
            locked.record_ns(ns);
        }
        let snap = atomic.snapshot();
        assert_eq!(snap.count, locked.count());
        assert_eq!(snap.max_ns, locked.max_ns());
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(snap.quantile_ns(q), locked.quantile_ns(q), "q={q}");
        }
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let snap = AtomicHistogram::new().snapshot();
        assert!(snap.is_empty());
        assert_eq!(snap.mean_ns(), 0.0);
        assert_eq!(snap.quantile_ns(0.5), 0);
    }

    #[test]
    fn quantiles_bound_true_values() {
        let h = AtomicHistogram::new();
        for ns in 1..=1000u64 {
            h.record_ns(ns);
        }
        let snap = h.snapshot();
        let p50 = snap.quantile_ns(0.5);
        // true median 500; reported value is a ≤2× upper bound
        assert!((500..=1024).contains(&p50), "p50={p50}");
        assert!(snap.quantile_ns(0.99) >= 990);
        assert_eq!(snap.max_ns, 1000);
    }

    #[test]
    fn json_roundtrip_preserves_counts() {
        let h = AtomicHistogram::new();
        for ns in [5u64, 5, 120, 4096, 1 << 40] {
            h.record_ns(ns);
        }
        let snap = h.snapshot();
        let parsed = Json::parse(&snap.to_json().render()).unwrap();
        let restored = HistSnapshot::from_json(&parsed).unwrap();
        assert_eq!(restored, snap);
    }

    #[test]
    fn add_merges_counts() {
        let a = AtomicHistogram::new();
        a.record_ns(10);
        let b = AtomicHistogram::new();
        b.record_ns(1000);
        b.record_ns(2000);
        a.add(&b.snapshot());
        let snap = a.snapshot();
        assert_eq!(snap.count, 3);
        assert_eq!(snap.max_ns, 2000);
        assert_eq!(snap.sum_ns, 3010);
    }

    #[test]
    fn from_json_rejects_garbage() {
        for bad in ["{}", "{\"count\":1}", "{\"count\":1,\"sum_ns\":1,\"max_ns\":1,\"buckets\":[[99,1]]}"]
        {
            let v = Json::parse(bad).unwrap();
            assert!(HistSnapshot::from_json(&v).is_err(), "accepted {bad}");
        }
    }
}
