//! First-class observability: per-stage tracing, lock-light latency
//! histograms, and the structured telemetry surface behind the
//! `STATS2` and `TRACE` wire verbs.
//!
//! ```text
//!   engines ──┐  coarse/refine/scan spans        ┌──► STATS2 [json|text]
//!   router  ──┼► Recorder ──► ObsSnapshot ──────┤     (stage histograms,
//!   batcher ──┘  retry/hedge/batch-wait,         │      per-engine counters)
//!                per-engine counters             └──► obs-*.snap generations
//!                                                     (crash-safe store;
//!   TRACE <x> <y> <k> ──► QueryTrace span tree         restored on boot)
//! ```
//!
//! Layering: `obs` sits beside `util` at the bottom of the crate — the
//! engines and the coordinator both depend on it, never the reverse.
//! Wire rendering uses the in-repo [`json`] module (serde is not in the
//! offline vendor set). Formats are documented in
//! `docs/OBSERVABILITY.md`.

pub mod hist;
pub mod json;
pub mod recorder;
pub mod trace;

pub use hist::{AtomicHistogram, HistSnapshot};
pub use json::Json;
pub use recorder::{EngineCounters, ObsSnapshot, Recorder};
pub use trace::{QueryTrace, SearchStep, SearchTrace, Stage, StageSpan};
