//! ASCII line plots — regenerates the paper's Fig. 3 *as a figure* in
//! the terminal and in bench logs (no plotting libraries offline).
//!
//! Log-log or lin-lin scatter of multiple labeled series over a
//! character canvas, with axes and legends.

/// One labeled series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
    pub marker: char,
}

impl Series {
    pub fn new(label: impl Into<String>, marker: char) -> Self {
        Self { label: label.into(), points: Vec::new(), marker }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Plot configuration.
#[derive(Debug, Clone)]
pub struct PlotSpec {
    pub title: String,
    pub width: usize,
    pub height: usize,
    pub log_x: bool,
    pub log_y: bool,
    pub x_label: String,
    pub y_label: String,
}

impl PlotSpec {
    pub fn new(title: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            width: 72,
            height: 22,
            log_x: false,
            log_y: false,
            x_label: "x".into(),
            y_label: "y".into(),
        }
    }

    pub fn loglog(mut self) -> Self {
        self.log_x = true;
        self.log_y = true;
        self
    }

    pub fn labels(mut self, x: impl Into<String>, y: impl Into<String>) -> Self {
        self.x_label = x.into();
        self.y_label = y.into();
        self
    }
}

fn transform(v: f64, log: bool) -> f64 {
    if log {
        v.max(f64::MIN_POSITIVE).log10()
    } else {
        v
    }
}

/// Render series onto an ASCII canvas.
pub fn render(spec: &PlotSpec, series: &[Series]) -> String {
    let (w, h) = (spec.width, spec.height);
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .map(|(x, y)| (transform(x, spec.log_x), transform(y, spec.log_y)))
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if all.is_empty() {
        return format!("{}\n(no data)\n", spec.title);
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    // avoid zero extent
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut canvas = vec![vec![' '; w]; h];
    for s in series {
        for &(px, py) in &s.points {
            let tx = transform(px, spec.log_x);
            let ty = transform(py, spec.log_y);
            if !(tx.is_finite() && ty.is_finite()) {
                continue;
            }
            let cx = ((tx - x0) / (x1 - x0) * (w - 1) as f64).round() as usize;
            let cy = ((ty - y0) / (y1 - y0) * (h - 1) as f64).round() as usize;
            let row = h - 1 - cy.min(h - 1);
            canvas[row][cx.min(w - 1)] = s.marker;
        }
    }
    let fmt_tick = |v: f64, log: bool| -> String {
        let raw = if log { 10f64.powf(v) } else { v };
        if raw.abs() >= 1000.0 {
            format!("{:.0e}", raw)
        } else if raw.abs() >= 1.0 {
            format!("{raw:.1}")
        } else {
            format!("{raw:.2e}")
        }
    };
    let mut out = String::new();
    out.push_str(&format!("{}\n", spec.title));
    out.push_str(&format!(
        "y: {} [{} .. {}]{}\n",
        spec.y_label,
        fmt_tick(y0, spec.log_y),
        fmt_tick(y1, spec.log_y),
        if spec.log_y { " (log)" } else { "" }
    ));
    for row in &canvas {
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(w));
    out.push('\n');
    out.push_str(&format!(
        "x: {} [{} .. {}]{}\n",
        spec.x_label,
        fmt_tick(x0, spec.log_x),
        fmt_tick(x1, spec.log_x),
        if spec.log_x { " (log)" } else { "" }
    ));
    for s in series {
        out.push_str(&format!("  {} = {}\n", s.marker, s.label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_series() -> Vec<Series> {
        let mut linear = Series::new("linear", 'x');
        let mut flat = Series::new("flat", 'o');
        for i in 1..=6 {
            let n = 10f64.powi(i);
            linear.push(n, n * 1e-6);
            flat.push(n, 3e-3);
        }
        vec![linear, flat]
    }

    #[test]
    fn renders_markers_and_legend() {
        let spec = PlotSpec::new("fig3").loglog().labels("N", "secs");
        let text = render(&spec, &demo_series());
        assert!(text.contains('x'));
        assert!(text.contains('o'));
        assert!(text.contains("x = linear"));
        assert!(text.contains("o = flat"));
        assert!(text.contains("(log)"));
    }

    #[test]
    fn empty_series_safe() {
        let spec = PlotSpec::new("empty");
        let text = render(&spec, &[Series::new("nothing", '.')]);
        assert!(text.contains("no data"));
    }

    #[test]
    fn linear_series_spans_canvas_diagonal() {
        let spec = PlotSpec::new("diag").loglog();
        let text = render(&spec, &demo_series()[..1].to_vec());
        let rows: Vec<&str> = text.lines().filter(|l| l.starts_with('|')).collect();
        // first canvas row (max y) holds the largest point, last the smallest
        assert!(rows.first().unwrap().contains('x'));
        assert!(rows.last().unwrap().contains('x'));
    }

    #[test]
    fn constant_series_no_zero_division() {
        let mut s = Series::new("const", '#');
        s.push(1.0, 5.0);
        s.push(2.0, 5.0);
        let text = render(&PlotSpec::new("c"), &[s]);
        assert!(text.contains('#'));
    }
}
