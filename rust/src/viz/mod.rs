//! Figure rendering: binary PPM (P6) images reproducing the paper's
//! Fig. 1 (vectors → image) and Fig. 2 (active search circles), plus
//! ASCII line plots ([`plot`]) for Fig. 3 — no plotting dependencies.

pub mod plot;

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::active::SearchTrace;
use crate::data::Dataset;
use crate::error::{AsnnError, Result};
use crate::grid::MultiGrid;

/// RGB raster canvas.
#[derive(Debug, Clone)]
pub struct Canvas {
    pub width: usize,
    pub height: usize,
    /// Row-major RGB triplets.
    pixels: Vec<u8>,
}

/// Distinct per-class colors (cycled when classes exceed the palette).
pub const PALETTE: [[u8; 3]; 8] = [
    [220, 50, 47],   // red
    [38, 139, 210],  // blue
    [133, 153, 0],   // green
    [181, 137, 0],   // yellow
    [211, 54, 130],  // magenta
    [42, 161, 152],  // cyan
    [203, 75, 22],   // orange
    [108, 113, 196], // violet
];

impl Canvas {
    pub fn new(width: usize, height: usize) -> Self {
        Self { width, height, pixels: vec![255u8; width * height * 3] }
    }

    #[inline]
    pub fn set(&mut self, x: i64, y: i64, rgb: [u8; 3]) {
        if x < 0 || y < 0 || x >= self.width as i64 || y >= self.height as i64 {
            return;
        }
        let i = (y as usize * self.width + x as usize) * 3;
        self.pixels[i..i + 3].copy_from_slice(&rgb);
    }

    pub fn get(&self, x: usize, y: usize) -> [u8; 3] {
        let i = (y * self.width + x) * 3;
        [self.pixels[i], self.pixels[i + 1], self.pixels[i + 2]]
    }

    /// Filled square dot of side `2*size+1`.
    pub fn dot(&mut self, x: i64, y: i64, size: i64, rgb: [u8; 3]) {
        for dy in -size..=size {
            for dx in -size..=size {
                self.set(x + dx, y + dy, rgb);
            }
        }
    }

    /// Midpoint circle outline.
    pub fn circle(&mut self, cx: i64, cy: i64, r: i64, rgb: [u8; 3]) {
        if r <= 0 {
            self.set(cx, cy, rgb);
            return;
        }
        let (mut x, mut y) = (r, 0i64);
        let mut err = 1 - r;
        while x >= y {
            for &(px, py) in &[
                (cx + x, cy + y),
                (cx - x, cy + y),
                (cx + x, cy - y),
                (cx - x, cy - y),
                (cx + y, cy + x),
                (cx - y, cy + x),
                (cx + y, cy - x),
                (cx - y, cy - x),
            ] {
                self.set(px, py, rgb);
            }
            y += 1;
            if err < 0 {
                err += 2 * y + 1;
            } else {
                x -= 1;
                err += 2 * (y - x) + 1;
            }
        }
    }

    /// A '+' marker (the paper's query symbol in Fig. 2).
    pub fn plus(&mut self, x: i64, y: i64, arm: i64, rgb: [u8; 3]) {
        for d in -arm..=arm {
            self.set(x + d, y, rgb);
            self.set(x, y + d, rgb);
        }
    }

    /// Write binary PPM (P6).
    pub fn save_ppm(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        write!(w, "P6\n{} {}\n255\n", self.width, self.height)?;
        w.write_all(&self.pixels)?;
        w.flush()?;
        Ok(())
    }
}

/// Fig. 1 (left): points as a scatter on a white canvas, colored by
/// class — "15 data points as 2 dimensional vectors".
pub fn render_scatter(ds: &Dataset, side: usize, dot: i64) -> Result<Canvas> {
    if ds.dim != 2 {
        return Err(AsnnError::Data("render_scatter requires 2-D data".into()));
    }
    let mut canvas = Canvas::new(side, side);
    let (mins, maxs) = ds.bounds();
    let sx = (side - 1) as f64 / (maxs[0] - mins[0]).max(f64::MIN_POSITIVE);
    let sy = (side - 1) as f64 / (maxs[1] - mins[1]).max(f64::MIN_POSITIVE);
    for i in 0..ds.len() {
        let p = ds.point(i);
        let x = ((p[0] - mins[0]) * sx) as i64;
        // flip y so the image matches plot orientation
        let y = (side as i64 - 1) - ((p[1] - mins[1]) * sy) as i64;
        let color = PALETTE[ds.label(i) as usize % PALETTE.len()];
        canvas.dot(x, y, dot, color);
    }
    Ok(canvas)
}

/// Fig. 1 (right) / Fig. 2 base: the count image itself, one color per
/// class (pixel colored by its majority class; white = empty).
pub fn render_grid(grid: &MultiGrid, dot: i64) -> Canvas {
    let r = grid.resolution();
    let mut canvas = Canvas::new(r, r);
    for py in 0..r as u32 {
        for px in 0..r as u32 {
            if grid.count_at(px, py) == 0 {
                continue;
            }
            let counts = grid.class_counts_at(px, py);
            let best = counts
                .iter()
                .enumerate()
                .max_by_key(|(_, &c)| c)
                .map(|(c, _)| c)
                .unwrap_or(0);
            let color = PALETTE[best % PALETTE.len()];
            let y = (r as i64 - 1) - py as i64;
            canvas.dot(px as i64, y, dot, color);
        }
    }
    canvas
}

/// Fig. 2: overlay the query '+' and every trace circle on the grid
/// image. Early circles fade to gray; the final circle is black.
pub fn render_trace(
    grid: &MultiGrid,
    query_px: (u32, u32),
    trace: &SearchTrace,
    dot: i64,
) -> Canvas {
    let mut canvas = render_grid(grid, dot);
    let r = grid.resolution() as i64;
    let flip = |py: u32| (r - 1) - py as i64;
    let n = trace.steps.len().max(1);
    for (i, step) in trace.steps.iter().enumerate() {
        let shade = if i + 1 == n {
            [0u8, 0, 0]
        } else {
            let g = 200u8.saturating_sub((i * 120 / n) as u8);
            [g, g, g]
        };
        canvas.circle(query_px.0 as i64, flip(query_px.1), step.r as i64, shade);
    }
    canvas.plus(query_px.0 as i64, flip(query_px.1), (dot * 4).max(6), [0, 0, 0]);
    canvas
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::active::SearchStep;
    use crate::data::synthetic::{generate, SyntheticSpec};

    #[test]
    fn canvas_set_get_and_bounds() {
        let mut c = Canvas::new(10, 10);
        c.set(3, 4, [1, 2, 3]);
        assert_eq!(c.get(3, 4), [1, 2, 3]);
        c.set(-1, 0, [9, 9, 9]); // silently ignored
        c.set(10, 0, [9, 9, 9]);
        assert_eq!(c.get(0, 0), [255, 255, 255]);
    }

    #[test]
    fn circle_is_hollow_and_centered() {
        let mut c = Canvas::new(41, 41);
        c.circle(20, 20, 10, [0, 0, 0]);
        assert_eq!(c.get(30, 20), [0, 0, 0]);
        assert_eq!(c.get(20, 30), [0, 0, 0]);
        assert_eq!(c.get(20, 20), [255, 255, 255]); // center untouched
    }

    #[test]
    fn scatter_marks_all_classes() {
        let ds = generate(&SyntheticSpec::paper_default(200, 77));
        let c = render_scatter(&ds, 200, 1).unwrap();
        // at least one pixel of each class color present
        for class in 0..3 {
            let target = PALETTE[class];
            let mut found = false;
            'outer: for y in 0..200 {
                for x in 0..200 {
                    if c.get(x, y) == target {
                        found = true;
                        break 'outer;
                    }
                }
            }
            assert!(found, "class {class} color missing");
        }
    }

    #[test]
    fn grid_render_nonwhite_matches_occupancy() {
        let ds = generate(&SyntheticSpec::paper_default(500, 78));
        let grid = MultiGrid::build(&ds, 100).unwrap();
        let c = render_grid(&grid, 0);
        let mut colored = 0;
        for y in 0..100 {
            for x in 0..100 {
                if c.get(x, y) != [255, 255, 255] {
                    colored += 1;
                }
            }
        }
        assert_eq!(colored, grid.occupied_cells());
    }

    #[test]
    fn trace_render_draws_final_black_circle() {
        let ds = generate(&SyntheticSpec::paper_default(500, 79));
        let grid = MultiGrid::build(&ds, 200).unwrap();
        let trace = SearchTrace {
            steps: vec![SearchStep { r: 30, n: 2 }, SearchStep { r: 50, n: 11 }],
            converged: true,
            ..Default::default()
        };
        let c = render_trace(&grid, (100, 100), &trace, 0);
        // final circle r=50: pixel at (150, flip(100)) should be black
        assert_eq!(c.get(150, 99), [0, 0, 0]);
    }

    #[test]
    fn ppm_roundtrip_header() {
        let c = Canvas::new(4, 3);
        let path = std::env::temp_dir().join(format!("asnn-viz-{}.ppm", std::process::id()));
        c.save_ppm(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P6\n4 3\n255\n"));
        assert_eq!(bytes.len(), 11 + 4 * 3 * 3);
        std::fs::remove_file(path).ok();
    }
}
