//! Crash injection for the durability layer — the disk-side sibling of
//! [`ChaosEngine`](crate::engine::chaos::ChaosEngine).
//!
//! A [`ChaosWriter`] simulates a process dying mid-write: bytes up to
//! a crash offset reach the underlying file, everything after is
//! silently discarded, and the caller is told the write succeeded —
//! exactly the lie a killed process's page cache tells. The crash
//! offset is either explicit (so tests can sweep *every* byte
//! boundary) or drawn from the seeded deterministic [`Rng`].

use std::fs::File;
use std::io::{self, Write};
use std::path::Path;

use crate::util::rng::Rng;

/// `Write` impl that stops persisting after `crash_at` bytes.
pub struct ChaosWriter {
    file: File,
    crash_at: u64,
    /// Bytes the caller believes it wrote.
    claimed: u64,
    /// Bytes that actually reached the file.
    persisted: u64,
}

impl ChaosWriter {
    /// Writer that persists exactly the first `crash_at` bytes of
    /// whatever is written through it.
    pub fn crash_after(path: &Path, crash_at: u64) -> io::Result<Self> {
        Ok(Self { file: File::create(path)?, crash_at, claimed: 0, persisted: 0 })
    }

    /// Writer whose crash offset is drawn uniformly from
    /// `[0, max_len]` using the seeded generator; returns the chosen
    /// offset so the test can assert against it.
    pub fn crash_randomly(path: &Path, max_len: u64, seed: u64) -> io::Result<(Self, u64)> {
        let mut rng = Rng::new(seed);
        let crash_at = rng.below(max_len + 1);
        Ok((Self::crash_after(path, crash_at)?, crash_at))
    }

    /// Bytes the caller was told were written.
    pub fn claimed(&self) -> u64 {
        self.claimed
    }

    /// Bytes that actually hit the file.
    pub fn persisted(&self) -> u64 {
        self.persisted
    }

    /// Whether the simulated crash point was reached.
    pub fn crashed(&self) -> bool {
        self.claimed > self.persisted || self.claimed >= self.crash_at
    }

    /// One-shot helper: write `bytes` to `path` through a crash at
    /// `crash_at`, syncing what survived. Returns bytes persisted.
    pub fn torn_write(path: &Path, bytes: &[u8], crash_at: u64) -> io::Result<u64> {
        let mut w = Self::crash_after(path, crash_at)?;
        w.write_all(bytes)?;
        w.flush()?;
        w.file.sync_all()?;
        Ok(w.persisted())
    }
}

impl Write for ChaosWriter {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let room = self.crash_at.saturating_sub(self.persisted);
        let survive = (buf.len() as u64).min(room) as usize;
        if survive > 0 {
            self.file.write_all(&buf[..survive])?;
            self.persisted += survive as u64;
        }
        self.claimed += buf.len() as u64;
        // Report full success: the dying process never learns its
        // tail was lost.
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        self.file.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("asnn-chaoswriter-{}-{name}", std::process::id()));
        p
    }

    #[test]
    fn truncates_at_exact_offset() {
        let path = tmp("exact");
        for cut in [0u64, 1, 7, 16, 31, 32] {
            let persisted = ChaosWriter::torn_write(&path, &[0xAA; 32], cut).unwrap();
            assert_eq!(persisted, cut.min(32));
            assert_eq!(fs::metadata(&path).unwrap().len(), cut.min(32));
        }
        fs::remove_file(&path).ok();
    }

    #[test]
    fn caller_is_lied_to() {
        let path = tmp("lie");
        let mut w = ChaosWriter::crash_after(&path, 4).unwrap();
        // chunked writes straddling the crash point all "succeed"
        w.write_all(&[1, 2, 3]).unwrap();
        w.write_all(&[4, 5, 6]).unwrap();
        w.write_all(&[7]).unwrap();
        assert_eq!(w.claimed(), 7);
        assert_eq!(w.persisted(), 4);
        assert!(w.crashed());
        drop(w);
        assert_eq!(fs::read(&path).unwrap(), vec![1, 2, 3, 4]);
        fs::remove_file(&path).ok();
    }

    #[test]
    fn random_offsets_are_deterministic_and_in_range() {
        let path = tmp("random");
        for seed in 0..50u64 {
            let (_, a) = ChaosWriter::crash_randomly(&path, 100, seed).unwrap();
            let (_, b) = ChaosWriter::crash_randomly(&path, 100, seed).unwrap();
            assert_eq!(a, b, "seed {seed} not deterministic");
            assert!(a <= 100);
        }
        fs::remove_file(&path).ok();
    }
}
