//! Bounds-checked little-endian cursors for snapshot payloads.
//!
//! Snapshot bodies are parsed from untrusted bytes (a torn or tampered
//! file may carry a valid checksum yet nonsense lengths after a version
//! skew), so every read is bounds-checked and returns a structured
//! [`AsnnError::Store`] instead of panicking or slicing out of range.

use crate::error::{AsnnError, Result};

/// Read cursor over a byte slice; all integers are little-endian.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn short(&self, want: usize) -> AsnnError {
        AsnnError::Store(format!(
            "payload truncated: need {want} bytes at offset {}, have {}",
            self.pos,
            self.remaining()
        ))
    }

    /// Take `n` raw bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(self.short(n));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Assert the payload is fully consumed (trailing garbage is as
    /// suspicious as a short read).
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(AsnnError::Store(format!(
                "payload has {} trailing bytes after offset {}",
                self.remaining(),
                self.pos
            )));
        }
        Ok(())
    }
}

/// Append-only little-endian writer (a thin `Vec<u8>` wrapper that
/// mirrors [`ByteReader`] so encode/decode read symmetrically).
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap) }
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = ByteWriter::with_capacity(64);
        w.u16(0xBEEF);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 7);
        w.f64(-0.125);
        w.bytes(b"xyz");
        let v = w.into_vec();

        let mut r = ByteReader::new(&v);
        assert_eq!(r.u16().unwrap(), 0xBEEF);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 7);
        assert_eq!(r.f64().unwrap(), -0.125);
        assert_eq!(r.take(3).unwrap(), b"xyz");
        r.finish().unwrap();
    }

    #[test]
    fn short_read_is_error_not_panic() {
        let mut r = ByteReader::new(&[1, 2, 3]);
        assert!(r.u64().is_err());
        // a failed read consumes nothing
        assert_eq!(r.remaining(), 3);
        assert_eq!(r.u16().unwrap(), 0x0201);
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut r = ByteReader::new(&[0, 0, 9]);
        r.u16().unwrap();
        assert!(r.finish().is_err());
    }
}
