//! Generation-numbered snapshot files with corrupt-file quarantine.
//!
//! A [`SnapshotStore`] owns a family of files `<prefix>-<seq>.snap`
//! inside one state directory. [`save`](SnapshotStore::save) publishes
//! a new generation atomically and prunes old ones down to `keep`;
//! [`load_latest`](SnapshotStore::load_latest) walks generations
//! newest-first, quarantining any that fail frame validation, and
//! returns the first valid payload — so one torn write (or several)
//! costs at most the newest generations, never the ability to boot.

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::Result;

use super::{decode_framed, quarantine, write_framed_atomic};

/// Frame magic for snapshot generation files.
pub const SNAP_MAGIC: &[u8; 8] = b"ASNNSNP1";

/// A validated snapshot returned by [`SnapshotStore::load_latest`].
#[derive(Debug)]
pub struct LoadedSnapshot {
    /// Generation number the payload came from.
    pub seq: u64,
    /// The frame payload (caller-defined encoding).
    pub payload: Vec<u8>,
    /// Corrupt newer generations quarantined on the way here.
    pub quarantined: Vec<PathBuf>,
}

/// One named family of snapshot generations in a state directory.
#[derive(Debug, Clone)]
pub struct SnapshotStore {
    dir: PathBuf,
    prefix: String,
    keep: usize,
}

impl SnapshotStore {
    /// `keep` is clamped to at least 1 — a store that retains zero
    /// generations cannot recover anything.
    pub fn new(dir: impl Into<PathBuf>, prefix: impl Into<String>, keep: usize) -> Self {
        Self { dir: dir.into(), prefix: prefix.into(), keep: keep.max(1) }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn prefix(&self) -> &str {
        &self.prefix
    }

    fn path_for(&self, seq: u64) -> PathBuf {
        // zero-padded so lexicographic and numeric order agree in `ls`
        self.dir.join(format!("{}-{seq:08}.snap", self.prefix))
    }

    /// Parse `<prefix>-<seq>.snap` back to its sequence number.
    fn seq_of(&self, path: &Path) -> Option<u64> {
        let name = path.file_name()?.to_str()?;
        let rest = name.strip_prefix(&self.prefix)?.strip_prefix('-')?;
        rest.strip_suffix(".snap")?.parse().ok()
    }

    /// All generations on disk for this prefix, sorted oldest-first.
    /// A missing directory is an empty list (first boot).
    pub fn generations(&self) -> Result<Vec<(u64, PathBuf)>> {
        let entries = match fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(e.into()),
        };
        let mut gens = Vec::new();
        for entry in entries {
            let path = entry?.path();
            if let Some(seq) = self.seq_of(&path) {
                gens.push((seq, path));
            }
        }
        gens.sort_unstable();
        Ok(gens)
    }

    /// Frame `payload` and publish it atomically as the next
    /// generation, then prune generations beyond `keep`. Returns the
    /// new generation number and path.
    pub fn save(&self, payload: &[u8]) -> Result<(u64, PathBuf)> {
        let gens = self.generations()?;
        let seq = gens.last().map(|&(s, _)| s + 1).unwrap_or(1);
        let path = self.path_for(seq);
        write_framed_atomic(&path, SNAP_MAGIC, payload)?;
        // prune: everything except the newest `keep` (the one just
        // written included)
        let total = gens.len() + 1;
        if total > self.keep {
            for (_, old) in gens.iter().take(total - self.keep) {
                let _ = fs::remove_file(old);
            }
        }
        Ok((seq, path))
    }

    /// Walk generations newest-first and return the first that passes
    /// frame validation. Corrupt generations encountered on the way
    /// are quarantined to `<path>.corrupt` (listed in the result so
    /// the caller can count them). `Ok(None)` means no valid snapshot
    /// exists — cold boot.
    pub fn load_latest(&self) -> Result<Option<LoadedSnapshot>> {
        let mut quarantined = Vec::new();
        for (seq, path) in self.generations()?.into_iter().rev() {
            let bytes = fs::read(&path)?;
            match decode_framed(SNAP_MAGIC, &bytes) {
                Ok(payload) => {
                    return Ok(Some(LoadedSnapshot {
                        seq,
                        payload: payload.to_vec(),
                        quarantined,
                    }));
                }
                Err(err) => {
                    let dest = quarantine(&path)?;
                    eprintln!(
                        "store: corrupt_quarantined path={} quarantined_to={} reason=\"{err}\"",
                        path.display(),
                        dest.display()
                    );
                    quarantined.push(dest);
                }
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(name: &str, keep: usize) -> SnapshotStore {
        let mut p = std::env::temp_dir();
        p.push(format!("asnn-snap-test-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        SnapshotStore::new(p, "gen", keep)
    }

    fn cleanup(s: &SnapshotStore) {
        fs::remove_dir_all(s.dir()).ok();
    }

    #[test]
    fn empty_store_cold_boots() {
        let s = store("empty", 3);
        assert!(s.generations().unwrap().is_empty());
        assert!(s.load_latest().unwrap().is_none());
    }

    #[test]
    fn save_load_roundtrip() {
        let s = store("roundtrip", 3);
        let (seq, path) = s.save(b"generation one").unwrap();
        assert_eq!(seq, 1);
        assert!(path.ends_with("gen-00000001.snap"));
        let loaded = s.load_latest().unwrap().unwrap();
        assert_eq!(loaded.seq, 1);
        assert_eq!(loaded.payload, b"generation one");
        assert!(loaded.quarantined.is_empty());
        cleanup(&s);
    }

    #[test]
    fn newest_generation_wins() {
        let s = store("newest", 5);
        s.save(b"one").unwrap();
        s.save(b"two").unwrap();
        s.save(b"three").unwrap();
        assert_eq!(s.load_latest().unwrap().unwrap().payload, b"three");
        cleanup(&s);
    }

    #[test]
    fn prunes_to_keep() {
        let s = store("prune", 2);
        for i in 0..5u8 {
            s.save(&[i]).unwrap();
        }
        let gens = s.generations().unwrap();
        assert_eq!(gens.len(), 2);
        assert_eq!(gens[0].0, 4);
        assert_eq!(gens[1].0, 5);
        cleanup(&s);
    }

    #[test]
    fn torn_newest_falls_back_and_quarantines() {
        let s = store("torn", 3);
        s.save(b"good").unwrap();
        let (_, newest) = s.save(b"about to tear").unwrap();
        let bytes = fs::read(&newest).unwrap();
        fs::write(&newest, &bytes[..bytes.len() - 3]).unwrap();

        let loaded = s.load_latest().unwrap().unwrap();
        assert_eq!(loaded.payload, b"good");
        assert_eq!(loaded.quarantined.len(), 1);
        assert!(!newest.exists());
        assert!(loaded.quarantined[0].to_string_lossy().ends_with(".corrupt"));
        // a second load no longer sees the quarantined file
        let again = s.load_latest().unwrap().unwrap();
        assert!(again.quarantined.is_empty());
        cleanup(&s);
    }

    #[test]
    fn all_torn_means_cold_boot() {
        let s = store("alltorn", 3);
        for payload in [b"a".as_slice(), b"bb", b"ccc"] {
            let (_, p) = s.save(payload).unwrap();
            fs::write(&p, b"x").unwrap();
        }
        assert!(s.load_latest().unwrap().is_none());
        cleanup(&s);
    }

    #[test]
    fn foreign_files_ignored() {
        let s = store("foreign", 3);
        s.save(b"real").unwrap();
        fs::write(s.dir().join("other-00000009.snap"), b"not ours").unwrap();
        fs::write(s.dir().join("notes.txt"), b"also not ours").unwrap();
        let gens = s.generations().unwrap();
        assert_eq!(gens.len(), 1);
        cleanup(&s);
    }
}
