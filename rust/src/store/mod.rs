//! Crash-safe durable storage: atomic writes, checksummed framing, and
//! startup recovery.
//!
//! Every on-disk artifact the coordinator may reload after a restart
//! goes through this module so that a crash at *any* byte of a write
//! can never be mistaken for valid state:
//!
//! - [`atomic_write`] publishes a file all-or-nothing: write
//!   `<path>.tmp`, fsync, rename over `<path>`, fsync the directory.
//!   Readers see either the old complete file or the new complete file.
//! - [`encode_framed`]/[`decode_framed`] wrap a payload in a versioned
//!   8-byte magic header and a 16-byte footer (declared length + CRC32
//!   + footer magic) so torn, truncated, or bit-rotted files fail
//!   validation at every possible truncation point.
//! - [`recover`] sweeps a state directory on boot: orphaned `.tmp`
//!   files (writes that never committed) are deleted, corrupt `.snap`
//!   files are quarantined to `<path>.corrupt` with a structured log
//!   line, and the caller falls back to the newest valid generation
//!   via [`SnapshotStore::load_latest`].
//!
//! The crash-injection harness ([`ChaosWriter`]) simulates a process
//! dying mid-write at an arbitrary byte offset; `tests/crash_recovery.rs`
//! drives it through every truncation point of a snapshot and proves
//! the server still boots and serves from the previous generation.

pub mod bytes;
pub mod chaos;
pub mod checksum;
pub mod snapshot;

pub use bytes::{ByteReader, ByteWriter};
pub use chaos::ChaosWriter;
pub use checksum::crc32;
pub use snapshot::{LoadedSnapshot, SnapshotStore, SNAP_MAGIC};

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use crate::error::{AsnnError, Result};

/// Trailer sentinel: the last four bytes of every framed file. A torn
/// write that loses the tail loses this first.
pub const FOOTER_MAGIC: &[u8; 4] = b"ASFT";

/// Bytes added around a payload by framing: 8 (header magic) + 8
/// (declared payload length) + 4 (CRC32) + 4 (footer magic).
pub const FRAME_OVERHEAD: usize = 24;

/// Frame `payload` for disk: `magic ‖ payload ‖ len:u64 ‖ crc:u32 ‖
/// FOOTER_MAGIC`. The CRC covers everything before it (header magic,
/// payload, and declared length), so no prefix of a frame is a valid
/// frame and no header/length corruption goes unnoticed.
pub fn encode_framed(magic: &[u8; 8], payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(payload.len() + FRAME_OVERHEAD);
    buf.extend_from_slice(magic);
    buf.extend_from_slice(payload);
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf.extend_from_slice(FOOTER_MAGIC);
    buf
}

/// Validate a frame produced by [`encode_framed`] and return the
/// payload slice. Every check failure names what was violated so the
/// quarantine log line says *why* a file was rejected.
pub fn decode_framed<'a>(magic: &[u8; 8], bytes: &'a [u8]) -> Result<&'a [u8]> {
    let n = bytes.len();
    if n < FRAME_OVERHEAD {
        return Err(AsnnError::Store(format!(
            "file truncated: {n} bytes, a frame needs at least {FRAME_OVERHEAD}"
        )));
    }
    if &bytes[..8] != magic {
        return Err(AsnnError::Store(format!(
            "bad header magic (expected {:?})",
            String::from_utf8_lossy(magic)
        )));
    }
    if &bytes[n - 4..] != FOOTER_MAGIC {
        return Err(AsnnError::Store("missing footer magic (torn write?)".into()));
    }
    let declared = u64::from_le_bytes(bytes[n - 16..n - 8].try_into().unwrap());
    let actual = (n - FRAME_OVERHEAD) as u64;
    if declared != actual {
        return Err(AsnnError::Store(format!(
            "length mismatch: footer declares {declared} payload bytes, file carries {actual}"
        )));
    }
    let stored = u32::from_le_bytes(bytes[n - 8..n - 4].try_into().unwrap());
    let computed = crc32(&bytes[..n - 8]);
    if stored != computed {
        return Err(AsnnError::Store(format!(
            "checksum mismatch: stored {stored:#010x}, computed {computed:#010x}"
        )));
    }
    Ok(&bytes[8..n - 16])
}

/// `<path>.tmp` — the staging name used by [`atomic_write`].
fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Write `bytes` to `path` all-or-nothing: stage in `<path>.tmp`,
/// fsync, rename into place, then fsync the parent directory so the
/// rename itself survives power loss. A crash at any point leaves
/// either the previous complete file or the new complete file at
/// `path` — never a prefix. Not safe for concurrent writers to the
/// same `path` (the staging name would collide); the snapshotter is
/// single-threaded by construction.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    };
    fs::create_dir_all(&dir)?;
    let tmp = tmp_path(path);
    let staged = (|| -> Result<()> {
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        fs::rename(&tmp, path)?;
        Ok(())
    })();
    if staged.is_err() {
        // best-effort cleanup; recover() reaps anything left behind
        let _ = fs::remove_file(&tmp);
        return staged;
    }
    // Directory fsync makes the rename durable. Best-effort: not every
    // platform/filesystem lets a directory be opened for sync.
    if let Ok(d) = File::open(&dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// [`encode_framed`] + [`atomic_write`] in one step.
pub fn write_framed_atomic(path: &Path, magic: &[u8; 8], payload: &[u8]) -> Result<()> {
    atomic_write(path, &encode_framed(magic, payload))
}

/// Read and validate a framed file, returning the payload.
pub fn read_framed(path: &Path, magic: &[u8; 8]) -> Result<Vec<u8>> {
    let bytes = fs::read(path)?;
    let payload = decode_framed(magic, &bytes)?;
    Ok(payload.to_vec())
}

/// Move a corrupt file out of the way as `<path>.corrupt` (kept for
/// post-mortem inspection rather than deleted). Returns the new path.
pub fn quarantine(path: &Path) -> Result<PathBuf> {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".corrupt");
    let dest = path.with_file_name(name);
    // rename() won't overwrite on all platforms; a stale quarantine of
    // the same file is superseded by the fresh one.
    let _ = fs::remove_file(&dest);
    fs::rename(path, &dest)?;
    Ok(dest)
}

/// What [`recover`] found and did in a state directory.
#[derive(Debug, Default)]
pub struct RecoveryReport {
    /// Snapshot files examined.
    pub scanned: usize,
    /// Snapshot files that passed frame validation.
    pub valid: usize,
    /// Corrupt snapshots moved to `<path>.corrupt`.
    pub quarantined: Vec<PathBuf>,
    /// Orphaned `.tmp` staging files deleted.
    pub removed_tmp: Vec<PathBuf>,
}

impl RecoveryReport {
    /// One-line `key=value` form for the boot log.
    pub fn summary(&self) -> String {
        format!(
            "scanned={} valid={} quarantined={} tmp_removed={}",
            self.scanned,
            self.valid,
            self.quarantined.len(),
            self.removed_tmp.len()
        )
    }
}

/// Startup recovery sweep over a state directory: delete orphaned
/// `.tmp` staging files (uncommitted writes), validate every `.snap`
/// frame, and quarantine corrupt ones to `<path>.corrupt` with a
/// structured `store:` log line. A missing directory is an empty
/// report, not an error — first boot has nothing to recover.
pub fn recover(dir: &Path) -> Result<RecoveryReport> {
    let mut report = RecoveryReport::default();
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(report),
        Err(e) => return Err(e.into()),
    };
    for entry in entries {
        let path = entry?.path();
        let ext = match path.extension().and_then(|e| e.to_str()) {
            Some(e) => e,
            None => continue,
        };
        if ext == "tmp" {
            fs::remove_file(&path)?;
            eprintln!("store: removed_orphan_tmp path={}", path.display());
            report.removed_tmp.push(path);
        } else if ext == "snap" {
            report.scanned += 1;
            let bytes = fs::read(&path)?;
            match decode_framed(SNAP_MAGIC, &bytes) {
                Ok(_) => report.valid += 1,
                Err(err) => {
                    let dest = quarantine(&path)?;
                    eprintln!(
                        "store: corrupt_quarantined path={} quarantined_to={} reason=\"{err}\"",
                        path.display(),
                        dest.display()
                    );
                    report.quarantined.push(dest);
                }
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("asnn-store-test-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        fs::create_dir_all(&p).unwrap();
        p
    }

    const MAGIC: &[u8; 8] = b"ASNNTST1";

    #[test]
    fn frame_roundtrip() {
        let payload = b"hello snapshot";
        let framed = encode_framed(MAGIC, payload);
        assert_eq!(framed.len(), payload.len() + FRAME_OVERHEAD);
        assert_eq!(decode_framed(MAGIC, &framed).unwrap(), payload);
    }

    #[test]
    fn empty_payload_frames() {
        let framed = encode_framed(MAGIC, b"");
        assert_eq!(decode_framed(MAGIC, &framed).unwrap(), b"");
    }

    #[test]
    fn every_truncation_point_rejected() {
        let framed = encode_framed(MAGIC, b"0123456789a");
        for cut in 0..framed.len() {
            assert!(
                decode_framed(MAGIC, &framed[..cut]).is_err(),
                "truncation to {cut}/{} bytes accepted",
                framed.len()
            );
        }
    }

    #[test]
    fn every_byte_corruption_rejected() {
        let framed = encode_framed(MAGIC, b"payload under test");
        for i in 0..framed.len() {
            let mut bad = framed.clone();
            bad[i] ^= 0x40;
            assert!(decode_framed(MAGIC, &bad).is_err(), "flip at byte {i} accepted");
        }
    }

    #[test]
    fn wrong_magic_rejected() {
        let framed = encode_framed(MAGIC, b"x");
        assert!(decode_framed(b"ASNNTST2", &framed).is_err());
    }

    #[test]
    fn atomic_write_replaces_and_cleans_tmp() {
        let dir = tmp_dir("atomic");
        let path = dir.join("state.snap");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"second");
        assert!(!tmp_path(&path).exists(), "staging file left behind");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_read_framed_file() {
        let dir = tmp_dir("framed");
        let path = dir.join("x.snap");
        write_framed_atomic(&path, MAGIC, b"abc").unwrap();
        assert_eq!(read_framed(&path, MAGIC).unwrap(), b"abc");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recover_missing_dir_is_empty_report() {
        let dir = tmp_dir("gone");
        fs::remove_dir_all(&dir).unwrap();
        let report = recover(&dir).unwrap();
        assert_eq!(report.scanned, 0);
        assert!(report.quarantined.is_empty());
    }

    #[test]
    fn recover_reaps_tmp_and_quarantines_torn_snap() {
        let dir = tmp_dir("recover");
        // one good snapshot, one torn one, one orphaned staging file
        let good = dir.join("a.snap");
        atomic_write(&good, &encode_framed(SNAP_MAGIC, b"good")).unwrap();
        let torn = dir.join("b.snap");
        let full = encode_framed(SNAP_MAGIC, b"soon to be torn");
        fs::write(&torn, &full[..full.len() / 2]).unwrap();
        fs::write(dir.join("c.snap.tmp"), b"never committed").unwrap();

        let report = recover(&dir).unwrap();
        assert_eq!(report.scanned, 2);
        assert_eq!(report.valid, 1);
        assert_eq!(report.quarantined.len(), 1);
        assert_eq!(report.removed_tmp.len(), 1);
        assert!(good.exists());
        assert!(!torn.exists());
        assert!(dir.join("b.snap.corrupt").exists());
        assert!(!dir.join("c.snap.tmp").exists());
        assert!(report.summary().contains("quarantined=1"));
        fs::remove_dir_all(&dir).ok();
    }
}
