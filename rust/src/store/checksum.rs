//! CRC32 (IEEE 802.3, reflected, polynomial `0xEDB88320`).
//!
//! Hand-rolled table-based implementation — the crate is std-only, and
//! a 256-entry table is plenty for snapshot-sized payloads. The exact
//! variant matters only for self-consistency (we never interoperate
//! with external CRC tooling), but IEEE is chosen so `crc32("123456789")
//! == 0xCBF43926`, the standard check value, stays verifiable.

/// 256-entry lookup table, one XOR+shift step per input byte.
const TABLE: [u32; 256] = make_table();

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// CRC32 of `bytes` in one shot.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_check_value() {
        // The canonical CRC32 test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn empty_input() {
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn single_bit_flip_changes_crc() {
        let a = b"the quick brown fox".to_vec();
        let base = crc32(&a);
        for i in 0..a.len() {
            for bit in 0..8 {
                let mut b = a.clone();
                b[i] ^= 1 << bit;
                assert_ne!(crc32(&b), base, "flip at byte {i} bit {bit} undetected");
            }
        }
    }

    #[test]
    fn truncation_changes_crc() {
        let a = vec![0xABu8; 64];
        let base = crc32(&a);
        for cut in 0..a.len() {
            assert_ne!(crc32(&a[..cut]), base, "truncation at {cut} undetected");
        }
    }
}
