//! `asnn` — Active Search for Nearest Neighbors.
//!
//! Reproduction of Um & Choi, *Active Search for Nearest Neighbors*
//! (cs.LG 2019) as a three-layer serving library:
//!
//! - **L3 (this crate)**: coordinator — grid index, engines, router,
//!   batcher, TCP server, metrics, CLI.
//! - **L2/L1 (python/, build-time only)**: JAX model + Pallas kernels,
//!   AOT-lowered to HLO text in `artifacts/`, executed from
//!   [`runtime`] via the PJRT CPU client.
//!
//! Quickstart:
//!
//! ```no_run
//! use asnn::data::synthetic::{SyntheticSpec, generate};
//! use asnn::grid::MultiGrid;
//! use asnn::engine::{NnEngine, active::ActiveEngine, brute::BruteEngine};
//!
//! let ds = generate(&SyntheticSpec::paper_default(10_000, 42));
//! let grid = MultiGrid::build(&ds, 3000).unwrap();
//! let engine = ActiveEngine::from_grid(grid, Default::default());
//! let hits = engine.knn(&[0.5, 0.5], 11).unwrap();
//! assert_eq!(hits.len(), 11);
//! ```

pub mod active;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod grid;
pub mod obs;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod store;
pub mod util;
pub mod viz;

pub use error::{AsnnError, Result};
