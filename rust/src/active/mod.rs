//! The paper's active-search machinery, decomposed:
//!
//! - [`radius`] — the Eq. 1 radius-update policy plus the convergence
//!   guards a production system needs (bracketing/bisection, max-iter);
//! - [`scan`] — circle counting and candidate collection over the count
//!   image (the computational hot spot the paper discusses in §3);
//! - [`window`] — static window-size selection for the AOT-compiled
//!   PJRT artifacts (the "zoom level" of the visual-system metaphor).

pub mod radius;
pub mod scan;
pub mod window;

// The search trace began life here as a debug struct; it is now the
// crate-wide stable trace record (see `crate::obs::trace`). Re-exported
// so paper-level code keeps reading `active::SearchTrace`.
pub use crate::obs::trace::{SearchStep, SearchTrace};
