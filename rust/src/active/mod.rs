//! The paper's active-search machinery, decomposed:
//!
//! - [`radius`] — the Eq. 1 radius-update policy plus the convergence
//!   guards a production system needs (bracketing/bisection, max-iter);
//! - [`scan`] — circle counting and candidate collection over the count
//!   image (the computational hot spot the paper discusses in §3);
//! - [`window`] — static window-size selection for the AOT-compiled
//!   PJRT artifacts (the "zoom level" of the visual-system metaphor).

pub mod radius;
pub mod scan;
pub mod window;

/// One step of an active search, recorded for traces and Fig. 2.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchStep {
    /// Radius used this iteration (pixels).
    pub r: u32,
    /// Points counted inside the circle.
    pub n: u64,
}

/// Full trace of an active search (for Fig. 2 and diagnostics).
#[derive(Debug, Clone, Default)]
pub struct SearchTrace {
    pub steps: Vec<SearchStep>,
    /// True if the loop ended by |n−k| ≤ tolerance, false if it hit the
    /// max-iteration guard or the radius cap.
    pub converged: bool,
    /// Radius growth steps resolved from pyramid upper bounds alone —
    /// coarse-to-fine skips that never paid for an exact disk scan, so
    /// they appear in neither `steps` nor the work accounting.
    pub coarse_skips: u32,
}

impl SearchTrace {
    pub fn iterations(&self) -> usize {
        self.steps.len()
    }

    pub fn final_radius(&self) -> Option<u32> {
        self.steps.last().map(|s| s.r)
    }
}
