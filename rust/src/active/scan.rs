//! Circle scans over the count image — the computational hot spot.
//!
//! The paper (§3): "Most of the computational cost comes from checking
//! all the inner pixels of the current circle." The production scan
//! avoids a per-pixel distance test by computing, for each row `dy`,
//! the half-span of the disk: `dx ≤ √(r²−dy²)` (L2) or `dx ≤ r−|dy|`
//! (L1 diamond), then summing contiguous `u16` runs — sequential,
//! branch-light, SIMD-friendly. A naive per-pixel variant is kept as
//! the test oracle and the §Perf "before" baseline.

use crate::config::Metric;
use crate::grid::MultiGrid;

/// Inclusive pixel span `[x0, x1]` of a disk row, clipped to the image.
#[inline]
fn row_span(cx: i64, half: i64, res: i64) -> Option<(usize, usize)> {
    let x0 = (cx - half).max(0);
    let x1 = (cx + half).min(res - 1);
    if x0 > x1 {
        None
    } else {
        Some((x0 as usize, x1 as usize))
    }
}

/// Half-span of the disk at vertical offset `dy` (pixels), or None if
/// the row is outside the disk.
#[inline]
fn half_span(r: u32, dy: i64, metric: Metric) -> Option<i64> {
    let r = r as i64;
    let ady = dy.abs();
    if ady > r {
        return None;
    }
    Some(match metric {
        Metric::L2 => {
            let rem = (r * r - dy * dy) as f64;
            rem.sqrt().floor() as i64
        }
        Metric::L1 => r - ady,
    })
}

/// Count all points inside the disk of radius `r` (pixels) centered at
/// `(cx, cy)`. O(r): one O(1) prefix-table span lookup per disk row
/// (§Perf: replaced the O(πr²) per-pixel accumulation — see
/// [`count_in_disk_rowspan`] for the previous generation and
/// [`count_in_disk_naive`] for the original baseline).
pub fn count_in_disk(grid: &MultiGrid, cx: u32, cy: u32, r: u32, metric: Metric) -> u64 {
    let res = grid.resolution() as i64;
    let (cx, cy) = (cx as i64, cy as i64);
    let mut total = 0u64;
    let dy_lo = (-(r as i64)).max(-cy);
    let dy_hi = (r as i64).min(res - 1 - cy);
    for dy in dy_lo..=dy_hi {
        let Some(half) = half_span(r, dy, metric) else { continue };
        let Some((x0, x1)) = row_span(cx, half, res) else { continue };
        total += grid.row_span_count((cy + dy) as u32, x0 as u32, x1 as u32) as u64;
    }
    total
}

/// Previous-generation scan: contiguous `u16` row sums (O(πr²) touched
/// pixels, but sequential). Kept for the §Perf before/after and as a
/// second oracle.
pub fn count_in_disk_rowspan(grid: &MultiGrid, cx: u32, cy: u32, r: u32, metric: Metric) -> u64 {
    let res = grid.resolution() as i64;
    let (cx, cy) = (cx as i64, cy as i64);
    let mut total = 0u64;
    let dy_lo = (-(r as i64)).max(-cy);
    let dy_hi = (r as i64).min(res - 1 - cy);
    for dy in dy_lo..=dy_hi {
        let Some(half) = half_span(r, dy, metric) else { continue };
        let Some((x0, x1)) = row_span(cx, half, res) else { continue };
        let row = grid.total_row((cy + dy) as u32);
        let mut s = 0u32;
        for &v in &row[x0..=x1] {
            s += v as u32;
        }
        total += s as u64;
    }
    total
}

/// Naive per-pixel oracle for [`count_in_disk`] (tests + §Perf baseline).
pub fn count_in_disk_naive(grid: &MultiGrid, cx: u32, cy: u32, r: u32, metric: Metric) -> u64 {
    let res = grid.resolution() as i64;
    let (cx, cy) = (cx as i64, cy as i64);
    let mut total = 0u64;
    for dy in -(r as i64)..=(r as i64) {
        for dx in -(r as i64)..=(r as i64) {
            let inside = match metric {
                Metric::L2 => dx * dx + dy * dy <= (r as i64) * (r as i64),
                Metric::L1 => dx.abs() + dy.abs() <= r as i64,
            };
            if !inside {
                continue;
            }
            let x = cx + dx;
            let y = cy + dy;
            if x >= 0 && x < res && y >= 0 && y < res {
                total += grid.count_at(x as u32, y as u32) as u64;
            }
        }
    }
    total
}

/// Per-class counts inside the disk (the paper's classification vote:
/// one count image per class). `out.len() == grid.num_classes()`.
/// Bucket-driven: one binary-search pair per disk row, then only the
/// points actually inside are touched — O(r·log N + hits) instead of
/// O(πr²) pixel reads (§Perf).
pub fn class_counts_in_disk(
    grid: &MultiGrid,
    cx: u32,
    cy: u32,
    r: u32,
    metric: Metric,
    out: &mut [u64],
) {
    assert_eq!(out.len(), grid.num_classes());
    out.fill(0);
    let res = grid.resolution() as i64;
    let (cxi, cyi) = (cx as i64, cy as i64);
    let dy_lo = (-(r as i64)).max(-cyi);
    let dy_hi = (r as i64).min(res - 1 - cyi);
    for dy in dy_lo..=dy_hi {
        let Some(half) = half_span(r, dy, metric) else { continue };
        let Some((x0, x1)) = row_span(cxi, half, res) else { continue };
        let y = (cyi + dy) as u32;
        let cell0 = y * res as u32 + x0 as u32;
        let cell1 = y * res as u32 + x1 as u32;
        for &(_, pid) in grid.points_in_cell_range(cell0, cell1) {
            out[grid.label_of(pid) as usize] += 1;
        }
    }
}

/// A candidate point recovered from the final circle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub point_id: u32,
    /// Pixel-space squared distance (L2) or L1 distance from the query
    /// pixel to the candidate's pixel — the paper's retina-space metric.
    pub pixel_dist: f64,
}

/// Collect point ids of every occupied pixel in the disk, with their
/// pixel-space distances (used by both `approx` and `refined` modes).
/// Bucket-driven like [`class_counts_in_disk`] (§Perf).
pub fn collect_in_disk(
    grid: &MultiGrid,
    cx: u32,
    cy: u32,
    r: u32,
    metric: Metric,
) -> Vec<Candidate> {
    let mut out = Vec::new();
    collect_in_disk_into(grid, cx, cy, r, metric, &mut out);
    out
}

/// [`collect_in_disk`] into a caller-owned buffer (cleared first). The
/// batched hot path reuses one buffer per worker thread, so the
/// steady-state candidate sweep allocates nothing.
pub fn collect_in_disk_into(
    grid: &MultiGrid,
    cx: u32,
    cy: u32,
    r: u32,
    metric: Metric,
    out: &mut Vec<Candidate>,
) {
    let res = grid.resolution() as i64;
    let (cxi, cyi) = (cx as i64, cy as i64);
    out.clear();
    let dy_lo = (-(r as i64)).max(-cyi);
    let dy_hi = (r as i64).min(res - 1 - cyi);
    for dy in dy_lo..=dy_hi {
        let Some(half) = half_span(r, dy, metric) else { continue };
        let Some((x0, x1)) = row_span(cxi, half, res) else { continue };
        let y = (cyi + dy) as u32;
        let cell0 = y * res as u32 + x0 as u32;
        let cell1 = y * res as u32 + x1 as u32;
        for &(cell, pid) in grid.points_in_cell_range(cell0, cell1) {
            let dx = (cell - y * res as u32) as i64 - cxi;
            let pixel_dist = match metric {
                Metric::L2 => (dx * dx + dy * dy) as f64,
                Metric::L1 => (dx.abs() + dy.abs()) as f64,
            };
            out.push(Candidate { point_id: pid, pixel_dist });
        }
    }
}

/// Number of pixels a disk scan touches (cost model for §Perf and the
/// resolution ablation).
pub fn disk_pixels(r: u32, metric: Metric) -> u64 {
    let r = r as i64;
    let mut n = 0u64;
    for dy in -r..=r {
        if let Some(half) = half_span(r as u32, dy, metric) {
            n += (2 * half + 1) as u64;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn grid(n: usize, res: usize) -> MultiGrid {
        let ds = generate(&SyntheticSpec::paper_default(n, 21));
        MultiGrid::build(&ds, res).unwrap()
    }

    #[test]
    fn fast_scan_matches_naive_l2() {
        let g = grid(2000, 200);
        for &(cx, cy, r) in &[(100, 100, 10), (100, 100, 50), (5, 5, 20), (199, 0, 30), (0, 199, 7)] {
            assert_eq!(
                count_in_disk(&g, cx, cy, r, Metric::L2),
                count_in_disk_naive(&g, cx, cy, r, Metric::L2),
                "cx={cx} cy={cy} r={r}"
            );
        }
    }

    #[test]
    fn fast_scan_matches_naive_l1() {
        let g = grid(2000, 200);
        for &(cx, cy, r) in &[(100, 100, 10), (100, 100, 60), (3, 190, 25)] {
            assert_eq!(
                count_in_disk(&g, cx, cy, r, Metric::L1),
                count_in_disk_naive(&g, cx, cy, r, Metric::L1),
                "cx={cx} cy={cy} r={r}"
            );
        }
    }

    #[test]
    fn prefix_scan_matches_rowspan_scan() {
        let g = grid(3000, 250);
        for &(cx, cy, r) in &[(125, 125, 5), (125, 125, 80), (0, 0, 60), (249, 100, 33)] {
            for metric in [Metric::L2, Metric::L1] {
                assert_eq!(
                    count_in_disk(&g, cx, cy, r, metric),
                    count_in_disk_rowspan(&g, cx, cy, r, metric),
                    "cx={cx} cy={cy} r={r} {metric:?}"
                );
            }
        }
    }

    #[test]
    fn full_image_disk_counts_everything() {
        let g = grid(1000, 100);
        // radius covering the whole image (diagonal)
        let n = count_in_disk(&g, 50, 50, 200, Metric::L2);
        assert_eq!(n, 1000);
    }

    #[test]
    fn zero_radius_counts_center_pixel() {
        let g = grid(1000, 100);
        let n = count_in_disk(&g, 10, 10, 0, Metric::L2);
        assert_eq!(n, g.count_at(10, 10) as u64);
    }

    #[test]
    fn class_counts_sum_to_total() {
        let g = grid(3000, 150);
        let mut cls = vec![0u64; 3];
        for &(cx, cy, r) in &[(75, 75, 20), (10, 140, 35)] {
            class_counts_in_disk(&g, cx, cy, r, Metric::L2, &mut cls);
            let total = count_in_disk(&g, cx, cy, r, Metric::L2);
            assert_eq!(cls.iter().sum::<u64>(), total);
        }
    }

    #[test]
    fn collect_matches_count() {
        let g = grid(1500, 120);
        for &(cx, cy, r) in &[(60, 60, 15), (0, 0, 40)] {
            let cands = collect_in_disk(&g, cx, cy, r, Metric::L2);
            let n = count_in_disk(&g, cx, cy, r, Metric::L2);
            assert_eq!(cands.len() as u64, n);
            // all pixel distances within r² for L2
            for c in &cands {
                assert!(c.pixel_dist <= (r as f64) * (r as f64) + 1e-9);
            }
        }
    }

    #[test]
    fn collect_into_reuses_buffer_and_matches_fresh() {
        let g = grid(1500, 120);
        let mut buf = vec![Candidate { point_id: 999, pixel_dist: -1.0 }];
        for &(cx, cy, r) in &[(60, 60, 15), (0, 0, 40), (119, 119, 5)] {
            collect_in_disk_into(&g, cx, cy, r, Metric::L2, &mut buf);
            assert_eq!(buf, collect_in_disk(&g, cx, cy, r, Metric::L2));
        }
    }

    #[test]
    fn l1_disk_is_subset_of_l2_disk() {
        let g = grid(2000, 150);
        let l1 = count_in_disk(&g, 75, 75, 30, Metric::L1);
        let l2 = count_in_disk(&g, 75, 75, 30, Metric::L2);
        assert!(l1 <= l2, "l1={l1} l2={l2}");
    }

    #[test]
    fn disk_pixels_close_to_area() {
        // L2 pixel count ≈ πr²; L1 diamond = 2r²+2r+1
        let p2 = disk_pixels(100, Metric::L2) as f64;
        assert!((p2 - std::f64::consts::PI * 100.0 * 100.0).abs() / p2 < 0.02);
        assert_eq!(disk_pixels(100, Metric::L1), 2 * 100 * 100 + 2 * 100 + 1);
    }
}
