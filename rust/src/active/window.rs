//! Window-scale selection for the AOT artifact path.
//!
//! PJRT executables are shape-specialized, so the L2 model is lowered
//! once per window size (DESIGN.md §1). At query time the coordinator
//! picks the smallest compiled window that contains the current scan
//! circle — the discrete "zoom level".

/// Chooses among a fixed ascending set of compiled window sizes.
#[derive(Debug, Clone)]
pub struct WindowLadder {
    sizes: Vec<usize>,
}

impl WindowLadder {
    /// `sizes` must be non-empty; stored sorted ascending, deduped.
    pub fn new(mut sizes: Vec<usize>) -> Self {
        assert!(!sizes.is_empty(), "window ladder needs at least one size");
        sizes.sort_unstable();
        sizes.dedup();
        Self { sizes }
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    pub fn largest(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Smallest window that fully contains a disk of radius `r`
    /// (diameter `2r+1`), or `None` if even the largest is too small —
    /// the caller then falls back to the native scan (or tiles).
    pub fn select(&self, r: u32) -> Option<usize> {
        let need = 2 * r as usize + 1;
        self.sizes.iter().copied().find(|&w| w >= need)
    }

    /// Largest radius servable by any compiled window.
    pub fn max_radius(&self) -> u32 {
        ((self.largest() - 1) / 2) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> WindowLadder {
        WindowLadder::new(vec![512, 64, 128, 256, 128])
    }

    #[test]
    fn sorted_and_deduped() {
        assert_eq!(ladder().sizes(), &[64, 128, 256, 512]);
    }

    #[test]
    fn selects_smallest_fitting() {
        let l = ladder();
        assert_eq!(l.select(10), Some(64)); // needs 21
        assert_eq!(l.select(31), Some(64)); // needs 63
        assert_eq!(l.select(32), Some(128)); // needs 65
        assert_eq!(l.select(127), Some(256));
        assert_eq!(l.select(255), Some(512));
        assert_eq!(l.select(256), None); // needs 513
    }

    #[test]
    fn max_radius_consistent_with_select() {
        let l = ladder();
        let rmax = l.max_radius();
        assert!(l.select(rmax).is_some());
        assert!(l.select(rmax + 1).is_none());
    }

    #[test]
    #[should_panic]
    fn empty_ladder_panics() {
        WindowLadder::new(vec![]);
    }
}
