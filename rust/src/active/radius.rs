//! Radius-adaptation policy.
//!
//! The paper's Eq. 1 update is `r ← round(r·√(k/n))`, derived from the
//! count being proportional to circle area. Used verbatim it has three
//! failure modes a serving system must handle:
//!
//! 1. `n = 0` — the update divides by zero. We double the radius.
//! 2. **Oscillation** — `round` can cycle between a radius with `n < k`
//!    and one with `n > k` without ever hitting `n = k` (point counts
//!    are integers; no radius with exactly `k` may exist for the
//!    pixel-quantized circle). We detect the bracket and bisect.
//! 3. **Unbounded growth** — queries in empty corners push `r` past the
//!    image; we cap at the image diagonal and stop.
//!
//! `tolerance = 0` and a pure Eq.-1 trajectory reproduce the paper's
//! algorithm exactly until the first oscillation.

/// Outcome of one policy step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// |n − k| within tolerance: stop, current circle is the answer.
    Done,
    /// Try this radius next.
    Continue(u32),
    /// No radius with n ≈ k exists (bracket collapsed) — the caller
    /// should accept the better bracket side (carried radius).
    Settle(u32),
    /// Radius/iteration budget exhausted.
    Exhausted,
}

/// Eq. 1 with guards. Create one per query.
#[derive(Debug, Clone)]
pub struct RadiusPolicy {
    k: u64,
    tolerance: u64,
    max_iters: u32,
    r_max: u32,
    iters: u32,
    /// Count-growth exponent: 2 for the paper's image (n ∝ area ∝ r²),
    /// 3 for the volume extension (n ∝ r³).
    dim_exp: f64,
    /// Largest radius seen with n < k.
    lo: Option<u32>,
    /// Smallest radius seen with n > k.
    hi: Option<u32>,
}

impl RadiusPolicy {
    /// `r_max` is typically the image diagonal in pixels.
    pub fn new(k: usize, tolerance: u32, max_iters: u32, r_max: u32) -> Self {
        Self::with_exponent(k, tolerance, max_iters, r_max, 2.0)
    }

    /// Generalized policy: `n ∝ r^dim_exp` (the d-dimensional Eq. 1 —
    /// DESIGN.md §5, used by the 3-D volume extension).
    pub fn with_exponent(
        k: usize,
        tolerance: u32,
        max_iters: u32,
        r_max: u32,
        dim_exp: f64,
    ) -> Self {
        assert!(dim_exp >= 1.0);
        Self {
            k: k as u64,
            tolerance: tolerance as u64,
            max_iters,
            r_max: r_max.max(1),
            iters: 0,
            dim_exp,
            lo: None,
            hi: None,
        }
    }

    /// The paper's Eq. 1, exposed for tests and the PJRT artifact check.
    pub fn eq1(r: u32, k: u64, n: u64) -> u32 {
        Self::eq1_dim(r, k, n, 2.0)
    }

    /// d-dimensional Eq. 1: r ← round(r·(k/n)^(1/d)).
    pub fn eq1_dim(r: u32, k: u64, n: u64, dim_exp: f64) -> u32 {
        debug_assert!(n > 0);
        let next = (r as f64 * (k as f64 / n as f64).powf(1.0 / dim_exp)).round();
        next.max(1.0) as u32
    }

    /// Feed the observation `(r, n)`; get the next action.
    pub fn step(&mut self, r: u32, n: u64) -> Step {
        self.iters += 1;
        if n.abs_diff(self.k) <= self.tolerance {
            return Step::Done;
        }
        if self.iters >= self.max_iters {
            return Step::Exhausted;
        }

        // maintain the bracket
        if n < self.k {
            self.lo = Some(self.lo.map_or(r, |lo| lo.max(r)));
        } else {
            self.hi = Some(self.hi.map_or(r, |hi| hi.min(r)));
        }

        // bracket collapsed: radii differ by ≤1 yet neither hits k —
        // no integer radius attains n = k. Settle on the ≥k side so the
        // circle contains at least k points (refinement can trim).
        if let (Some(lo), Some(hi)) = (self.lo, self.hi) {
            if hi <= lo + 1 {
                return Step::Settle(hi);
            }
        }

        let mut next = if n == 0 {
            // Eq. 1 is undefined at n = 0 (paper doesn't treat it);
            // exponential growth mirrors the "zoom out" step.
            r.saturating_mul(2)
        } else {
            Self::eq1_dim(r, self.k, n, self.dim_exp)
        };

        // inside a bracket, keep the iterate strictly interior
        // (plain Eq. 1 can jump outside and oscillate forever)
        if let (Some(lo), Some(hi)) = (self.lo, self.hi) {
            if next <= lo || next >= hi {
                next = lo + (hi - lo) / 2;
            }
        }
        if next == r {
            // round() fix-point without convergence: nudge toward k
            next = if n < self.k { r + 1 } else { r.saturating_sub(1).max(1) };
        }
        if next > self.r_max {
            if self.hi.is_some() {
                // should not happen (hi bounds growth), but stay safe
                return Step::Settle(self.hi.unwrap());
            }
            if r >= self.r_max {
                return Step::Exhausted;
            }
            next = self.r_max;
        }
        Step::Continue(next)
    }

    pub fn iterations(&self) -> u32 {
        self.iters
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_matches_paper_examples() {
        // n == k keeps the radius
        assert_eq!(RadiusPolicy::eq1(100, 11, 11), 100);
        // too many points shrinks, too few grows, by the area ratio
        assert_eq!(RadiusPolicy::eq1(100, 11, 44), 50);
        assert_eq!(RadiusPolicy::eq1(50, 8, 2), 100);
        // never returns 0
        assert_eq!(RadiusPolicy::eq1(1, 1, 1_000_000), 1);
    }

    #[test]
    fn done_within_tolerance() {
        let mut p = RadiusPolicy::new(11, 0, 10, 1000);
        assert_eq!(p.step(100, 11), Step::Done);
        let mut p = RadiusPolicy::new(11, 2, 10, 1000);
        assert_eq!(p.step(100, 13), Step::Done);
        assert_eq!(p.step(100, 9), Step::Done);
    }

    #[test]
    fn zero_count_doubles() {
        let mut p = RadiusPolicy::new(11, 0, 10, 100_000);
        match p.step(100, 0) {
            Step::Continue(r) => assert_eq!(r, 200),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn growth_capped_at_r_max() {
        let mut p = RadiusPolicy::new(11, 0, 50, 150);
        match p.step(100, 0) {
            Step::Continue(r) => assert_eq!(r, 150),
            s => panic!("{s:?}"),
        }
        // at the cap with still nothing: exhausted
        assert_eq!(p.step(150, 0), Step::Exhausted);
    }

    #[test]
    fn oscillation_settles_on_upper_bracket() {
        // r=10 → n=9 (<k), r=11 → n=15 (>k): no radius gives exactly 11
        let mut p = RadiusPolicy::new(11, 0, 50, 1000);
        let s1 = p.step(10, 9);
        assert!(matches!(s1, Step::Continue(_)), "{s1:?}");
        let s2 = p.step(11, 15);
        assert_eq!(s2, Step::Settle(11));
    }

    #[test]
    fn bracket_forces_interior_iterate() {
        let mut p = RadiusPolicy::new(100, 0, 50, 10_000);
        // lo=10 (n too small), hi=100 (n too big)
        assert!(matches!(p.step(10, 5), Step::Continue(_)));
        let next = match p.step(100, 500) {
            Step::Continue(r) => r,
            s => panic!("{s:?}"),
        };
        assert!(next > 10 && next < 100, "next={next}");
    }

    #[test]
    fn max_iters_exhausts() {
        let mut p = RadiusPolicy::new(11, 0, 3, 100_000);
        assert!(matches!(p.step(1, 0), Step::Continue(_)));
        assert!(matches!(p.step(2, 0), Step::Continue(_)));
        assert_eq!(p.step(4, 0), Step::Exhausted);
        assert_eq!(p.iterations(), 3);
    }

    #[test]
    fn fixpoint_nudges() {
        // round(5 * sqrt(11/10)) = round(5.24) = 5 → would spin forever
        let mut p = RadiusPolicy::new(11, 0, 50, 1000);
        match p.step(5, 10) {
            Step::Continue(r) => assert_eq!(r, 6),
            s => panic!("{s:?}"),
        }
    }

    #[test]
    fn converges_on_synthetic_area_model() {
        // ideal model: n(r) = round(density * π r²); policy should reach
        // |n−k| ≤ 0 or settle within a few iterations for many densities
        for &density in &[0.001, 0.01, 0.1, 1.0] {
            let count = |r: u32| ((r as f64).powi(2) * std::f64::consts::PI * density).round() as u64;
            let mut p = RadiusPolicy::new(11, 0, 64, 100_000);
            let mut r = 100u32;
            let mut done = false;
            for _ in 0..64 {
                match p.step(r, count(r)) {
                    Step::Done | Step::Settle(_) => {
                        done = true;
                        break;
                    }
                    Step::Continue(next) => r = next,
                    Step::Exhausted => break,
                }
            }
            assert!(done, "density {density} did not converge");
        }
    }
}
