//! 3-D volume index — the paper's §3 higher-dimension sketch, built.
//!
//! "This approach can be applied to higher dimensional data, though it
//! will require a much bigger memory (or disk) space." A `R³` voxel
//! count volume with per-(z,y)-row prefix sums: the O(R^d) memory cost
//! the paper warns about is real ([`VolumeGrid::memory_bytes`]
//! quantifies it — that warning becomes the EXT-3D bench), while ball
//! counts stay O(r²) rows via the prefix table.

use crate::data::Dataset;
use crate::error::{AsnnError, Result};

/// Voxelized 3-D count volume with point buckets.
#[derive(Debug, Clone)]
pub struct VolumeGrid {
    resolution: usize,
    mins: [f64; 3],
    scale: [f64; 3],
    /// Voxel counts, `[z][y][x]` row-major.
    total: Vec<u16>,
    /// Per-(z,y)-row prefix sums: `prefix[(z*R+y)*(R+1)+x]`.
    row_prefix: Vec<u32>,
    /// `(voxel, point_id)` sorted by voxel.
    cell_points: Vec<(u32, u32)>,
    labels: Vec<u16>,
    num_classes: usize,
    n_points: usize,
}

impl VolumeGrid {
    /// Voxelize a 3-D dataset. Resolution is capped at 512 (a u32 cell
    /// index must hold R³, and memory is already ~0.5 GiB there —
    /// exactly the paper's caveat).
    pub fn build(ds: &Dataset, resolution: usize) -> Result<Self> {
        if ds.dim != 3 {
            return Err(AsnnError::Grid(format!(
                "VolumeGrid requires dim == 3 (got {})",
                ds.dim
            )));
        }
        if !(8..=512).contains(&resolution) {
            return Err(AsnnError::Grid("volume resolution must be in [8, 512]".into()));
        }
        if ds.is_empty() {
            return Err(AsnnError::Grid("cannot voxelize an empty dataset".into()));
        }
        let (mins_v, maxs_v) = ds.bounds();
        let r = resolution;
        let mut mins = [0.0; 3];
        let mut scale = [0.0; 3];
        for d in 0..3 {
            let extent = (maxs_v[d] - mins_v[d]).max(f64::MIN_POSITIVE);
            mins[d] = mins_v[d];
            scale[d] = r as f64 / extent;
        }
        let mut total = vec![0u16; r * r * r];
        let mut cell_points = Vec::with_capacity(ds.len());
        let this = |p: &[f64], d: usize| -> u32 {
            (((p[d] - mins[d]) * scale[d]).floor()).clamp(0.0, (r - 1) as f64) as u32
        };
        for i in 0..ds.len() {
            let p = ds.point(i);
            let (px, py, pz) = (this(p, 0), this(p, 1), this(p, 2));
            let cell = (pz * r as u32 + py) * r as u32 + px;
            total[cell as usize] = total[cell as usize].saturating_add(1);
            cell_points.push((cell, i as u32));
        }
        cell_points.sort_unstable();
        let mut row_prefix = vec![0u32; r * r * (r + 1)];
        for zy in 0..r * r {
            let mut acc = 0u32;
            let base = zy * (r + 1);
            for x in 0..r {
                acc += total[zy * r + x] as u32;
                row_prefix[base + x + 1] = acc;
            }
        }
        Ok(Self {
            resolution: r,
            mins,
            scale,
            total,
            row_prefix,
            cell_points,
            labels: ds.labels.clone(),
            num_classes: ds.num_classes,
            n_points: ds.len(),
        })
    }

    pub fn resolution(&self) -> usize {
        self.resolution
    }

    pub fn n_points(&self) -> usize {
        self.n_points
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Voxel of a data-space point (clamped to the volume).
    pub fn voxel_of(&self, p: &[f64]) -> (u32, u32, u32) {
        let r = self.resolution;
        let f = |d: usize| -> u32 {
            (((p[d] - self.mins[d]) * self.scale[d]).floor()).clamp(0.0, (r - 1) as f64) as u32
        };
        (f(0), f(1), f(2))
    }

    #[inline]
    fn row_count(&self, z: u32, y: u32, x0: u32, x1: u32) -> u32 {
        let r = self.resolution;
        let base = (z as usize * r + y as usize) * (r + 1);
        self.row_prefix[base + x1 as usize + 1] - self.row_prefix[base + x0 as usize]
    }

    /// Count points inside the L2 ball of radius `rad` voxels centered
    /// at `(cx, cy, cz)`: O(r²) prefix lookups.
    pub fn count_in_ball(&self, cx: u32, cy: u32, cz: u32, rad: u32) -> u64 {
        let res = self.resolution as i64;
        let (cx, cy, cz) = (cx as i64, cy as i64, cz as i64);
        let rad = rad as i64;
        let mut total = 0u64;
        for dz in (-rad).max(-cz)..=rad.min(res - 1 - cz) {
            let rem_z = rad * rad - dz * dz;
            let ry = (rem_z as f64).sqrt().floor() as i64;
            for dy in (-ry).max(-cy)..=ry.min(res - 1 - cy) {
                let rem = rem_z - dy * dy;
                if rem < 0 {
                    continue;
                }
                let half = (rem as f64).sqrt().floor() as i64;
                let x0 = (cx - half).max(0);
                let x1 = (cx + half).min(res - 1);
                if x0 > x1 {
                    continue;
                }
                total +=
                    self.row_count((cz + dz) as u32, (cy + dy) as u32, x0 as u32, x1 as u32)
                        as u64;
            }
        }
        total
    }

    /// Point ids (with labels) inside the ball, via bucket ranges.
    pub fn collect_in_ball(&self, cx: u32, cy: u32, cz: u32, rad: u32) -> Vec<(u32, u16)> {
        let res = self.resolution as i64;
        let (cxi, cyi, czi) = (cx as i64, cy as i64, cz as i64);
        let rad = rad as i64;
        let mut out = Vec::new();
        for dz in (-rad).max(-czi)..=rad.min(res - 1 - czi) {
            let rem_z = rad * rad - dz * dz;
            let ry = (rem_z as f64).sqrt().floor() as i64;
            for dy in (-ry).max(-cyi)..=ry.min(res - 1 - cyi) {
                let rem = rem_z - dy * dy;
                if rem < 0 {
                    continue;
                }
                let half = (rem as f64).sqrt().floor() as i64;
                let x0 = (cxi - half).max(0);
                let x1 = (cxi + half).min(res - 1);
                if x0 > x1 {
                    continue;
                }
                let row_base = ((czi + dz) * res + (cyi + dy)) as u32 * res as u32;
                let lo = self
                    .cell_points
                    .partition_point(|&(c, _)| c < row_base + x0 as u32);
                let hi = self
                    .cell_points
                    .partition_point(|&(c, _)| c <= row_base + x1 as u32);
                for &(_, pid) in &self.cell_points[lo..hi] {
                    out.push((pid, self.labels[pid as usize]));
                }
            }
        }
        out
    }

    /// Index memory in bytes — the paper's O(R^d) warning, measured.
    pub fn memory_bytes(&self) -> usize {
        self.total.len() * 2
            + self.row_prefix.len() * 4
            + self.cell_points.len() * 8
            + self.labels.len() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::data::Dataset;

    fn ds3(n: usize, seed: u64) -> Dataset {
        let mut spec = SyntheticSpec::paper_default(n, seed);
        spec.dim = 3;
        generate(&spec)
    }

    #[test]
    fn counts_conserved() {
        let ds = ds3(5000, 21);
        let v = VolumeGrid::build(&ds, 64).unwrap();
        let all = v.count_in_ball(32, 32, 32, 200);
        assert_eq!(all, 5000);
    }

    #[test]
    fn ball_count_matches_direct() {
        let ds = ds3(2000, 22);
        let v = VolumeGrid::build(&ds, 48).unwrap();
        let (cx, cy, cz, rad) = (24u32, 24u32, 24u32, 10u32);
        // direct: voxelize each point, test voxel distance
        let mut want = 0u64;
        for i in 0..ds.len() {
            let (px, py, pz) = v.voxel_of(ds.point(i));
            let dx = px as i64 - cx as i64;
            let dy = py as i64 - cy as i64;
            let dz = pz as i64 - cz as i64;
            if dx * dx + dy * dy + dz * dz <= (rad * rad) as i64 {
                want += 1;
            }
        }
        assert_eq!(v.count_in_ball(cx, cy, cz, rad), want);
    }

    #[test]
    fn collect_matches_count() {
        let ds = ds3(3000, 23);
        let v = VolumeGrid::build(&ds, 64).unwrap();
        for &(c, rad) in &[(32u32, 8u32), (5, 20), (60, 15)] {
            let n = v.count_in_ball(c, c, c, rad);
            let got = v.collect_in_ball(c, c, c, rad);
            assert_eq!(got.len() as u64, n);
        }
    }

    #[test]
    fn monotone_in_radius() {
        let ds = ds3(4000, 24);
        let v = VolumeGrid::build(&ds, 64).unwrap();
        let mut last = 0;
        for rad in (0..60).step_by(4) {
            let n = v.count_in_ball(32, 32, 32, rad);
            assert!(n >= last);
            last = n;
        }
    }

    #[test]
    fn memory_grows_cubically() {
        let ds = ds3(1000, 25);
        let small = VolumeGrid::build(&ds, 32).unwrap().memory_bytes();
        let big = VolumeGrid::build(&ds, 128).unwrap().memory_bytes();
        // 4× resolution → ~64× memory (paper's warning)
        assert!(big > small * 30, "small={small} big={big}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let ds2 = generate(&SyntheticSpec::paper_default(100, 26));
        assert!(VolumeGrid::build(&ds2, 64).is_err()); // dim 2
        let ds = ds3(100, 27);
        assert!(VolumeGrid::build(&ds, 4).is_err());
        assert!(VolumeGrid::build(&ds, 1024).is_err());
    }
}
