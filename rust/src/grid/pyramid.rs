//! Multi-resolution count pyramid — the "zooming in and out" of the
//! paper's human-visual-system metaphor, made concrete.
//!
//! Level 0 is the full-resolution total-count image; each higher level
//! halves the resolution by summing 2×2 blocks. Two uses:
//!
//! - **density-informed r₀** ([`Pyramid::suggest_r0`]): a coarse level
//!   gives a local density estimate in O(1), replacing the paper's
//!   fixed r₀ = 100 that §3 itself calls "too small";
//! - **coarse-to-fine counting**: a circle count at a coarse level
//!   bounds the fine count, letting the engine skip scan iterations.

use super::MultiGrid;
use crate::config::Metric;

/// Summed 2×2 reduction pyramid over the total-count image.
#[derive(Debug, Clone)]
pub struct Pyramid {
    /// `levels[l]` is a `res_l × res_l` row-major u32 image.
    levels: Vec<Vec<u32>>,
    /// Side length per level.
    resolutions: Vec<usize>,
    /// Per-level row prefix sums, `row_prefix[l][y * (res_l + 1) + x]`
    /// = points in row `y` strictly left of column `x` — O(1) row-span
    /// sums at every level for coarse-to-fine disk bounds.
    row_prefix: Vec<Vec<u32>>,
}

impl Pyramid {
    /// Build from a grid. Levels stop when resolution would drop
    /// below 8 pixels.
    ///
    /// Resolutions halve with `div_ceil`, so an odd trailing row or
    /// column folds into the last coarse cell instead of being
    /// dropped. That keeps the level sums equal to `n_points` at every
    /// level for every resolution — the invariant that makes a coarse
    /// count a sound **upper** bound on a fine count (a lossy level
    /// could under-count and wrongly let the engine skip a radius).
    /// The level-`l` cell `x` still covers exactly the level-0 range
    /// `[x·2^l, (x+1)·2^l − 1] ∩ image`, so `>> level` mapping holds.
    pub fn build(grid: &MultiGrid) -> Self {
        let r0 = grid.resolution();
        let mut levels: Vec<Vec<u32>> = Vec::new();
        let mut resolutions = Vec::new();
        let base: Vec<u32> = grid.total_image().iter().map(|&v| v as u32).collect();
        levels.push(base);
        resolutions.push(r0);
        loop {
            let prev_res = *resolutions.last().unwrap();
            let next_res = prev_res.div_ceil(2);
            if next_res < 8 {
                break;
            }
            let prev = levels.last().unwrap();
            let mut next = vec![0u32; next_res * next_res];
            // 2×2 reduction with the edge handling hoisted out of the
            // inner loop: interior destination cells always have both
            // source rows and columns in range, so they reduce via
            // bounds-check-free slice iterators; an odd trailing source
            // row/column is folded in once, outside the hot loop.
            let full = prev_res / 2;
            for (y, dst) in next.chunks_exact_mut(next_res).enumerate() {
                let row0 = &prev[(y * 2) * prev_res..(y * 2 + 1) * prev_res];
                if y < full {
                    let row1 = &prev[(y * 2 + 1) * prev_res..(y * 2 + 2) * prev_res];
                    for ((d, a), b) in dst[..full]
                        .iter_mut()
                        .zip(row0.chunks_exact(2))
                        .zip(row1.chunks_exact(2))
                    {
                        *d = a[0] + a[1] + b[0] + b[1];
                    }
                    if full < next_res {
                        dst[full] = row0[prev_res - 1] + row1[prev_res - 1];
                    }
                } else {
                    // odd trailing source row: single-row reduction
                    for (d, a) in dst[..full].iter_mut().zip(row0.chunks_exact(2)) {
                        *d = a[0] + a[1];
                    }
                    if full < next_res {
                        dst[full] = row0[prev_res - 1];
                    }
                }
            }
            levels.push(next);
            resolutions.push(next_res);
        }
        let row_prefix = levels
            .iter()
            .zip(&resolutions)
            .map(|(img, &res)| prefix_rows(img, res))
            .collect();
        Self { levels, resolutions, row_prefix }
    }

    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    pub fn resolution(&self, level: usize) -> usize {
        self.resolutions[level]
    }

    /// Count at a pixel of a level (pixel given in level-0 coordinates).
    pub fn count_at(&self, level: usize, px0: u32, py0: u32) -> u32 {
        let shift = level as u32;
        let res = self.resolutions[level];
        let x = (px0 >> shift).min(res as u32 - 1) as usize;
        let y = (py0 >> shift).min(res as u32 - 1) as usize;
        self.levels[level][y * res + x]
    }

    /// Local density (points per level-0 pixel²) around `(px, py)`,
    /// measured over a `3×3` block of the given level.
    pub fn local_density(&self, level: usize, px0: u32, py0: u32) -> f64 {
        let shift = level as u32;
        let res = self.resolutions[level] as i64;
        let cx = (px0 >> shift) as i64;
        let cy = (py0 >> shift) as i64;
        let mut count = 0u64;
        let mut cells = 0u64;
        for dy in -1..=1i64 {
            for dx in -1..=1i64 {
                let x = cx + dx;
                let y = cy + dy;
                if x >= 0 && x < res && y >= 0 && y < res {
                    count += self.levels[level][(y * res + x) as usize] as u64;
                    cells += 1;
                }
            }
        }
        let pixels_per_cell = (1u64 << (2 * level)) as f64;
        count as f64 / (cells as f64 * pixels_per_cell)
    }

    /// Density-informed initial radius: solve `k ≈ π r² ρ` for `r` using
    /// the local density at a mid pyramid level. Clamped to `[1, res/2]`.
    pub fn suggest_r0(&self, k: usize, px: u32, py: u32) -> u32 {
        let level = (self.num_levels() / 2).min(self.num_levels() - 1);
        let rho = self.local_density(level, px, py);
        let res = self.resolutions[0] as f64;
        if rho <= 0.0 {
            // empty neighbourhood: start wide
            return (res / 4.0) as u32;
        }
        let r = (k as f64 / (std::f64::consts::PI * rho)).sqrt();
        (r.round() as u32).clamp(1, (res / 2.0) as u32)
    }

    /// Points in columns `[x0, x1]` (inclusive, level coordinates) of
    /// row `y` at `level` — O(1) via the row prefix table.
    pub fn row_span_count(&self, level: usize, y: usize, x0: usize, x1: usize) -> u32 {
        let res = self.resolutions[level];
        debug_assert!(y < res && x0 <= x1 && x1 < res);
        let row = &self.row_prefix[level][y * (res + 1)..(y + 1) * (res + 1)];
        row[x1 + 1] - row[x0]
    }

    /// Upper bound on the points within radius `r` of the level-0
    /// pixel `(cx, cy)`, computed from `O(r / 2^level)` coarse row
    /// spans instead of `O(r)` fine ones.
    ///
    /// Soundness: every in-disk level-0 pixel lies in some scanned
    /// coarse cell (the per-row half-span is evaluated at the row's
    /// *closest* dy, which can only widen it), and a coarse cell's
    /// count includes all of its base pixels — so the sum can only
    /// over-count. At `level` 0 the bound degenerates to the exact
    /// [`crate::active::scan::count_in_disk`].
    pub fn count_in_disk_bound(
        &self,
        level: usize,
        cx: u32,
        cy: u32,
        r: u32,
        metric: Metric,
    ) -> u64 {
        let res = self.resolutions[level] as i64;
        let scale = 1i64 << level;
        let (cx, cy, r) = (cx as i64, cy as i64, r as i64);
        let ys0 = (cy - r).max(0) >> level;
        let ys1 = ((cy + r) >> level).min(res - 1);
        let mut total = 0u64;
        for ys in ys0..=ys1 {
            // minimal |dy| from cy to any level-0 row this coarse row covers
            let (lo, hi) = (ys * scale, (ys + 1) * scale - 1);
            let dy_min = if cy < lo {
                lo - cy
            } else if cy > hi {
                cy - hi
            } else {
                0
            };
            let Some(half) = half_span_wide(r, dy_min, metric) else { continue };
            let xs0 = (cx - half).max(0) >> level;
            let xs1 = ((cx + half) >> level).min(res - 1);
            if xs0 > xs1 {
                continue;
            }
            total += self.row_span_count(level, ys as usize, xs0 as usize, xs1 as usize) as u64;
        }
        total
    }

    /// Total memory of all levels (count images + row prefix tables).
    pub fn memory_bytes(&self) -> usize {
        let counts: usize = self.levels.iter().map(|l| l.len() * 4).sum();
        let prefixes: usize = self.row_prefix.iter().map(|p| p.len() * 4).sum();
        counts + prefixes
    }
}

/// Row prefix table for one level image (see `Pyramid::row_prefix`).
fn prefix_rows(img: &[u32], res: usize) -> Vec<u32> {
    let mut table = vec![0u32; res * (res + 1)];
    for (y, row) in img.chunks_exact(res).enumerate() {
        let dst = &mut table[y * (res + 1)..(y + 1) * (res + 1)];
        let mut acc = 0u32;
        for (d, &v) in dst[1..].iter_mut().zip(row) {
            acc += v;
            *d = acc;
        }
    }
    table
}

/// Widest x half-extent of the disk at row offset `dy` (same formula
/// as the scanner's private `half_span`, on the bound's i64 domain).
fn half_span_wide(r: i64, dy: i64, metric: Metric) -> Option<i64> {
    if dy > r {
        return None;
    }
    Some(match metric {
        Metric::L2 => (((r * r - dy * dy) as f64).sqrt().floor()) as i64,
        Metric::L1 => r - dy,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn pyr(n: usize, res: usize) -> (MultiGrid, Pyramid) {
        let ds = generate(&SyntheticSpec::paper_default(n, 13));
        let g = MultiGrid::build(&ds, res).unwrap();
        let p = Pyramid::build(&g);
        (g, p)
    }

    #[test]
    fn level_sums_preserved() {
        let (g, p) = pyr(3000, 256);
        let n = g.n_points() as u64;
        for l in 0..p.num_levels() {
            let s: u64 = p.levels[l].iter().map(|&v| v as u64).sum();
            assert_eq!(s, n, "level {l}");
        }
    }

    #[test]
    fn odd_resolution_edge_rows_and_columns_preserved() {
        // odd resolutions fold the trailing row/column into the last
        // coarse cell; with floor division those edge points would
        // silently vanish from every coarse level
        let (g, p) = pyr(3000, 257);
        let n = g.n_points() as u64;
        for l in 0..p.num_levels() {
            let s: u64 = p.levels[l].iter().map(|&v| v as u64).sum();
            assert_eq!(s, n, "level {l} lost edge points");
        }
        // 257 → 129: the last coarse column covers exactly base column 256
        let res1 = p.resolution(1);
        assert_eq!(res1, 129);
        let edge_col: u64 = (0..257u32).map(|y| g.count_at(256, y) as u64).sum();
        let coarse_edge: u64 =
            (0..res1).map(|y| p.levels[1][y * res1 + res1 - 1] as u64).sum();
        assert_eq!(coarse_edge, edge_col);
        // and the last coarse row covers exactly base row 256
        let edge_row: u64 = (0..257u32).map(|x| g.count_at(x, 256) as u64).sum();
        let coarse_row: u64 = p.levels[1][(res1 - 1) * res1..].iter().map(|&v| v as u64).sum();
        assert_eq!(coarse_row, edge_row);
    }

    #[test]
    fn row_span_count_matches_direct_sum() {
        let (_, p) = pyr(2000, 200);
        for level in 0..p.num_levels() {
            let res = p.resolution(level);
            for &(y, x0, x1) in &[(0, 0, res - 1), (res / 2, res / 3, res / 2), (res - 1, 0, 0)] {
                let direct: u32 = p.levels[level][y * res + x0..=y * res + x1].iter().sum();
                assert_eq!(p.row_span_count(level, y, x0, x1), direct, "level {level}");
            }
        }
    }

    #[test]
    fn disk_bound_is_sound_and_exact_at_level0() {
        use crate::active::scan;
        let ds = generate(&SyntheticSpec::paper_default(3000, 13));
        let g = MultiGrid::build(&ds, 257).unwrap();
        let p = Pyramid::build(&g);
        for &(cx, cy, r) in &[(128u32, 128u32, 20u32), (0, 0, 50), (256, 256, 9), (40, 200, 90)] {
            for metric in [Metric::L2, Metric::L1] {
                let exact = scan::count_in_disk(&g, cx, cy, r, metric);
                for level in 0..p.num_levels() {
                    let bound = p.count_in_disk_bound(level, cx, cy, r, metric);
                    assert!(
                        bound >= exact,
                        "level {level} cx={cx} cy={cy} r={r} {metric:?}: {bound} < {exact}"
                    );
                }
                assert_eq!(p.count_in_disk_bound(0, cx, cy, r, metric), exact);
            }
        }
    }

    #[test]
    fn level_count_and_resolutions() {
        let (_, p) = pyr(100, 256);
        assert_eq!(p.resolution(0), 256);
        assert_eq!(p.resolution(1), 128);
        assert!(p.num_levels() >= 5);
        // stops before dropping under 8
        assert!(p.resolution(p.num_levels() - 1) >= 8);
    }

    #[test]
    fn count_at_matches_grid_at_level0() {
        let (g, p) = pyr(500, 128);
        for py in (0..128).step_by(17) {
            for px in (0..128).step_by(13) {
                assert_eq!(p.count_at(0, px, py), g.count_at(px, py) as u32);
            }
        }
    }

    #[test]
    fn suggest_r0_tracks_density() {
        // dense uniform data → small suggested radius; tiny data → larger
        let (_, dense) = pyr(50_000, 512);
        let (_, sparse) = pyr(100, 512);
        let rd = dense.suggest_r0(11, 256, 256);
        let rs = sparse.suggest_r0(11, 256, 256);
        assert!(rd < rs, "dense={rd} sparse={rs}");
        assert!(rd >= 1);
    }

    #[test]
    fn density_positive_on_uniform() {
        let (_, p) = pyr(10_000, 256);
        let d = p.local_density(p.num_levels() / 2, 128, 128);
        assert!(d > 0.0);
        // uniform 10k over 256² ≈ 0.15 pts/pixel
        assert!((d - 10_000.0 / (256.0 * 256.0)).abs() < 0.1, "d={d}");
    }
}
