//! Multi-resolution count pyramid — the "zooming in and out" of the
//! paper's human-visual-system metaphor, made concrete.
//!
//! Level 0 is the full-resolution total-count image; each higher level
//! halves the resolution by summing 2×2 blocks. Two uses:
//!
//! - **density-informed r₀** ([`Pyramid::suggest_r0`]): a coarse level
//!   gives a local density estimate in O(1), replacing the paper's
//!   fixed r₀ = 100 that §3 itself calls "too small";
//! - **coarse-to-fine counting**: a circle count at a coarse level
//!   bounds the fine count, letting the engine skip scan iterations.

use super::MultiGrid;

/// Summed 2×2 reduction pyramid over the total-count image.
#[derive(Debug, Clone)]
pub struct Pyramid {
    /// `levels[l]` is a `res_l × res_l` row-major u32 image.
    levels: Vec<Vec<u32>>,
    /// Side length per level.
    resolutions: Vec<usize>,
}

impl Pyramid {
    /// Build from a grid. Levels stop when resolution would drop
    /// below 8 pixels.
    pub fn build(grid: &MultiGrid) -> Self {
        let r0 = grid.resolution();
        let mut levels: Vec<Vec<u32>> = Vec::new();
        let mut resolutions = Vec::new();
        let base: Vec<u32> = grid.total_image().iter().map(|&v| v as u32).collect();
        levels.push(base);
        resolutions.push(r0);
        loop {
            let prev_res = *resolutions.last().unwrap();
            let next_res = prev_res / 2;
            if next_res < 8 {
                break;
            }
            let prev = levels.last().unwrap();
            let mut next = vec![0u32; next_res * next_res];
            for y in 0..next_res {
                for x in 0..next_res {
                    let mut s = 0u32;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let sy = y * 2 + dy;
                            let sx = x * 2 + dx;
                            if sy < prev_res && sx < prev_res {
                                s += prev[sy * prev_res + sx];
                            }
                        }
                    }
                    next[y * next_res + x] = s;
                }
            }
            levels.push(next);
            resolutions.push(next_res);
        }
        Self { levels, resolutions }
    }

    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    pub fn resolution(&self, level: usize) -> usize {
        self.resolutions[level]
    }

    /// Count at a pixel of a level (pixel given in level-0 coordinates).
    pub fn count_at(&self, level: usize, px0: u32, py0: u32) -> u32 {
        let shift = level as u32;
        let res = self.resolutions[level];
        let x = (px0 >> shift).min(res as u32 - 1) as usize;
        let y = (py0 >> shift).min(res as u32 - 1) as usize;
        self.levels[level][y * res + x]
    }

    /// Local density (points per level-0 pixel²) around `(px, py)`,
    /// measured over a `3×3` block of the given level.
    pub fn local_density(&self, level: usize, px0: u32, py0: u32) -> f64 {
        let shift = level as u32;
        let res = self.resolutions[level] as i64;
        let cx = (px0 >> shift) as i64;
        let cy = (py0 >> shift) as i64;
        let mut count = 0u64;
        let mut cells = 0u64;
        for dy in -1..=1i64 {
            for dx in -1..=1i64 {
                let x = cx + dx;
                let y = cy + dy;
                if x >= 0 && x < res && y >= 0 && y < res {
                    count += self.levels[level][(y * res + x) as usize] as u64;
                    cells += 1;
                }
            }
        }
        let pixels_per_cell = (1u64 << (2 * level)) as f64;
        count as f64 / (cells as f64 * pixels_per_cell)
    }

    /// Density-informed initial radius: solve `k ≈ π r² ρ` for `r` using
    /// the local density at a mid pyramid level. Clamped to `[1, res/2]`.
    pub fn suggest_r0(&self, k: usize, px: u32, py: u32) -> u32 {
        let level = (self.num_levels() / 2).min(self.num_levels() - 1);
        let rho = self.local_density(level, px, py);
        let res = self.resolutions[0] as f64;
        if rho <= 0.0 {
            // empty neighbourhood: start wide
            return (res / 4.0) as u32;
        }
        let r = (k as f64 / (std::f64::consts::PI * rho)).sqrt();
        (r.round() as u32).clamp(1, (res / 2.0) as u32)
    }

    /// Total memory of all levels in bytes.
    pub fn memory_bytes(&self) -> usize {
        self.levels.iter().map(|l| l.len() * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn pyr(n: usize, res: usize) -> (MultiGrid, Pyramid) {
        let ds = generate(&SyntheticSpec::paper_default(n, 13));
        let g = MultiGrid::build(&ds, res).unwrap();
        let p = Pyramid::build(&g);
        (g, p)
    }

    #[test]
    fn level_sums_preserved() {
        let (g, p) = pyr(3000, 256);
        let n = g.n_points() as u64;
        for l in 0..p.num_levels() {
            let s: u64 = p.levels[l].iter().map(|&v| v as u64).sum();
            assert_eq!(s, n, "level {l}");
        }
    }

    #[test]
    fn level_count_and_resolutions() {
        let (_, p) = pyr(100, 256);
        assert_eq!(p.resolution(0), 256);
        assert_eq!(p.resolution(1), 128);
        assert!(p.num_levels() >= 5);
        // stops before dropping under 8
        assert!(p.resolution(p.num_levels() - 1) >= 8);
    }

    #[test]
    fn count_at_matches_grid_at_level0() {
        let (g, p) = pyr(500, 128);
        for py in (0..128).step_by(17) {
            for px in (0..128).step_by(13) {
                assert_eq!(p.count_at(0, px, py), g.count_at(px, py) as u32);
            }
        }
    }

    #[test]
    fn suggest_r0_tracks_density() {
        // dense uniform data → small suggested radius; tiny data → larger
        let (_, dense) = pyr(50_000, 512);
        let (_, sparse) = pyr(100, 512);
        let rd = dense.suggest_r0(11, 256, 256);
        let rs = sparse.suggest_r0(11, 256, 256);
        assert!(rd < rs, "dense={rd} sparse={rs}");
        assert!(rd >= 1);
    }

    #[test]
    fn density_positive_on_uniform() {
        let (_, p) = pyr(10_000, 256);
        let d = p.local_density(p.num_levels() / 2, 128, 128);
        assert!(d > 0.0);
        // uniform 10k over 256² ≈ 0.15 pts/pixel
        assert!((d - 10_000.0 / (256.0 * 256.0)).abs() < 0.1, "d={d}");
    }
}
