//! Serialization of [`MultiGrid`] for the coordinator's index
//! snapshots.
//!
//! Only the grid's *primary* data goes to disk — geometry, `(cell,
//! point_id)` assignments, labels. Derived state (count images, row
//! prefix sums) is recomputed on restore through
//! [`MultiGrid::from_parts`], which also fully validates the decoded
//! values: snapshot bytes are untrusted input even after the outer
//! CRC frame passes, since a version-skewed or hand-edited file can
//! carry a valid checksum over nonsense.

use super::{Geometry, MultiGrid};
use crate::error::{AsnnError, Result};
use crate::store::{self, ByteReader, ByteWriter};

/// Frame magic for grid snapshots (bump on layout change).
pub const GRID_MAGIC: &[u8; 8] = b"ASNNGRD1";

/// Decode-time guard rails: a hostile header may not demand absurd
/// allocations even when the arithmetic doesn't overflow.
const MAX_RESOLUTION: usize = 1 << 15;
const MAX_CLASSES: usize = 1 << 10;
/// Cap on total `u16` elements across the rebuilt count images.
const MAX_IMAGE_ELEMS: u64 = 1 << 31;

/// Serialize a grid to its framed snapshot image.
pub fn to_bytes(grid: &MultiGrid) -> Vec<u8> {
    let geom = grid.geometry();
    let (mins, maxs) = geom.bounds();
    let n = grid.n_points();
    let mut w = ByteWriter::with_capacity(64 + n * 10);
    w.u64(geom.resolution() as u64);
    w.f64(mins[0]);
    w.f64(mins[1]);
    w.f64(maxs[0]);
    w.f64(maxs[1]);
    w.u64(grid.num_classes() as u64);
    w.u64(n as u64);
    // child modules see the parent's private fields, so the snapshot
    // reads the primary arrays directly without widening MultiGrid's API
    for &(cell, pid) in &grid.cell_points {
        w.u32(cell);
        w.u32(pid);
    }
    for &label in &grid.labels {
        w.u16(label);
    }
    store::encode_framed(GRID_MAGIC, &w.into_vec())
}

/// Rebuild a grid from a framed snapshot image. The restored grid is
/// structurally identical to one built from the original dataset
/// (same sort order, same recomputed count images).
pub fn from_bytes(bytes: &[u8]) -> Result<MultiGrid> {
    let payload = store::decode_framed(GRID_MAGIC, bytes)?;
    let mut r = ByteReader::new(payload);
    let resolution = r.u64()? as usize;
    let mins = [r.f64()?, r.f64()?];
    let maxs = [r.f64()?, r.f64()?];
    let num_classes = r.u64()? as usize;
    let n = r.u64()? as usize;

    if !(8..=MAX_RESOLUTION).contains(&resolution) {
        return Err(AsnnError::Store(format!(
            "grid snapshot resolution {resolution} outside [8, {MAX_RESOLUTION}]"
        )));
    }
    if num_classes == 0 || num_classes > MAX_CLASSES {
        return Err(AsnnError::Store(format!(
            "grid snapshot class count {num_classes} outside [1, {MAX_CLASSES}]"
        )));
    }
    let elems = (resolution as u64)
        .pow(2)
        .checked_mul(1 + num_classes as u64)
        .ok_or_else(|| AsnnError::Store("grid snapshot image size overflows".into()))?;
    if elems > MAX_IMAGE_ELEMS {
        return Err(AsnnError::Store(format!(
            "grid snapshot would allocate {elems} image elements (cap {MAX_IMAGE_ELEMS})"
        )));
    }
    // bounds are validated by Geometry::new (finite, ordered); the
    // stored bounds are already padded, so no extra padding here —
    // the rebuilt affine map is bit-identical to the original.
    let geom = Geometry::new(resolution, mins, maxs, 0.0)?;

    // n is implicitly bounded by the payload length: each point costs
    // 10 bytes below, and ByteReader::take refuses short reads before
    // any allocation proportional to n happens.
    let mut cell_points = Vec::with_capacity(n.min(payload.len() / 10 + 1));
    for chunk in r.take(n.checked_mul(8).ok_or_else(|| count_overflow(n))?)?.chunks_exact(8) {
        let cell = u32::from_le_bytes(chunk[..4].try_into().unwrap());
        let pid = u32::from_le_bytes(chunk[4..].try_into().unwrap());
        cell_points.push((cell, pid));
    }
    let mut labels = Vec::with_capacity(n);
    for chunk in r.take(n.checked_mul(2).ok_or_else(|| count_overflow(n))?)?.chunks_exact(2) {
        labels.push(u16::from_le_bytes(chunk.try_into().unwrap()));
    }
    r.finish()?;
    MultiGrid::from_parts(geom, num_classes, cell_points, labels)
}

fn count_overflow(n: usize) -> AsnnError {
    AsnnError::Store(format!("grid snapshot point count {n} overflows"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::engine::{brute::BruteEngine, NnEngine};

    fn sample_grid(n: usize, res: usize) -> MultiGrid {
        let ds = generate(&SyntheticSpec::paper_default(n, 17));
        MultiGrid::build(&ds, res).unwrap()
    }

    #[test]
    fn roundtrip_is_structurally_identical() {
        let ds = generate(&SyntheticSpec::paper_default(500, 17));
        let grid = MultiGrid::build(&ds, 64).unwrap();
        let back = from_bytes(&to_bytes(&grid)).unwrap();

        assert_eq!(back.resolution(), grid.resolution());
        assert_eq!(back.num_classes(), grid.num_classes());
        assert_eq!(back.n_points(), grid.n_points());
        assert_eq!(back.geometry(), grid.geometry());
        assert_eq!(back.total_image(), grid.total_image());
        for py in 0..64u32 {
            for px in 0..64u32 {
                assert_eq!(back.class_counts_at(px, py), grid.class_counts_at(px, py));
                assert_eq!(
                    back.points_at(px, py).collect::<Vec<_>>(),
                    grid.points_at(px, py).collect::<Vec<_>>()
                );
            }
        }
        for pid in 0..grid.n_points() as u32 {
            assert_eq!(back.label_of(pid), grid.label_of(pid));
        }
        // the affine map is bit-identical: every dataset point lands
        // on the same pixel
        for i in 0..ds.len() {
            let p = ds.point(i);
            assert_eq!(
                back.geometry().pixel_of(p[0], p[1]),
                grid.geometry().pixel_of(p[0], p[1])
            );
        }
    }

    #[test]
    fn restored_grid_answers_queries() {
        let ds = generate(&SyntheticSpec::paper_default(400, 5));
        let grid = MultiGrid::build(&ds, 128).unwrap();
        let restored = from_bytes(&to_bytes(&grid)).unwrap();
        let active = crate::engine::active::ActiveEngine::from_grid(restored, Default::default());
        let brute = BruteEngine::new(std::sync::Arc::new(ds));
        let q = [0.4, 0.6];
        let a = active.knn(&q, 5).unwrap();
        let b = brute.knn(&q, 5).unwrap();
        assert_eq!(a.len(), b.len());
    }

    #[test]
    fn truncation_at_every_byte_rejected() {
        let grid = sample_grid(20, 16);
        let bytes = to_bytes(&grid);
        for cut in 0..bytes.len() {
            assert!(
                from_bytes(&bytes[..cut]).is_err(),
                "truncated grid snapshot ({cut}/{} bytes) accepted",
                bytes.len()
            );
        }
    }

    #[test]
    fn hostile_headers_rejected() {
        // valid frame, nonsense body: resolution beyond the cap
        let mut w = ByteWriter::with_capacity(64);
        w.u64(1 << 40);
        for v in [0.0, 0.0, 1.0, 1.0] {
            w.f64(v);
        }
        w.u64(3);
        w.u64(0);
        let framed = store::encode_framed(GRID_MAGIC, &w.into_vec());
        let err = from_bytes(&framed).unwrap_err().to_string();
        assert!(err.contains("resolution"), "{err}");

        // class count that would demand terabytes of count images
        let mut w = ByteWriter::with_capacity(64);
        w.u64(1 << 15);
        for v in [0.0, 0.0, 1.0, 1.0] {
            w.f64(v);
        }
        w.u64(1024);
        w.u64(0);
        let framed = store::encode_framed(GRID_MAGIC, &w.into_vec());
        let err = from_bytes(&framed).unwrap_err().to_string();
        assert!(err.contains("image elements"), "{err}");
    }

    #[test]
    fn wrong_payload_type_rejected() {
        // a dataset snapshot is not a grid snapshot
        let framed = store::encode_framed(b"ASNNDS02", b"whatever");
        assert!(from_bytes(&framed).is_err());
    }
}
