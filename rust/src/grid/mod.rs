//! The retina-space index: rasterized per-class count images plus a
//! pixel→point-id bucket index for exact neighbor recovery.
//!
//! The paper (§2) transforms the N points "onto an image", and for
//! classification keeps "as many images as the number of classes, each
//! pixel keeps the number of data points on it". [`MultiGrid`] is
//! exactly that, with two additions needed for a production system:
//!
//! 1. a `total` count image (sum over classes) so the radius-adaptation
//!    scan touches 2 bytes per pixel instead of `2·C`;
//! 2. a compact CSR-like cell→point-id map so the final circle can be
//!    resolved back to true point identities (and re-ranked by exact
//!    distance in `refined` mode).

pub mod geometry;
pub mod pyramid;
pub mod snapshot;
pub mod volume;

pub use geometry::Geometry;
pub use pyramid::Pyramid;
pub use volume::VolumeGrid;

use crate::data::Dataset;
use crate::error::{AsnnError, Result};

/// Per-class count images over a square pixel grid, plus point buckets.
#[derive(Debug, Clone)]
pub struct MultiGrid {
    geom: Geometry,
    num_classes: usize,
    /// Total counts, row-major `[y * R + x]`.
    total: Vec<u16>,
    /// Per-class counts, interleaved `[(y * R + x) * C + c]`.
    class_counts: Vec<u16>,
    /// `(cell, point_id)` sorted by cell — CSR without the offsets array
    /// (binary search keeps memory at 8 B/point instead of 4 B/cell).
    cell_points: Vec<(u32, u32)>,
    /// Per-point labels (bucket-driven class voting without the dataset).
    labels: Vec<u16>,
    /// Per-row prefix sums of `total`: `row_prefix[y*(R+1)+x]` = points
    /// in row `y`, columns `[0, x)`. Makes any row-span count O(1), so a
    /// disk count is O(r) instead of O(πr²) — the §Perf headline.
    row_prefix: Vec<u32>,
    n_points: usize,
}

impl MultiGrid {
    /// Rasterize a dataset onto an `resolution × resolution` image.
    /// Only 2-D datasets rasterize to a flat image (the paper's setting;
    /// see DESIGN.md §5 for the d > 2 discussion).
    pub fn build(ds: &Dataset, resolution: usize) -> Result<Self> {
        Self::build_padded(ds, resolution, 0.0)
    }

    /// [`build`](Self::build) with fractional padding around the data
    /// bounding box (so fresh queries near the hull map inside).
    pub fn build_padded(ds: &Dataset, resolution: usize, padding: f64) -> Result<Self> {
        if ds.dim != 2 {
            return Err(AsnnError::Grid(format!(
                "MultiGrid requires dim == 2 (got {}); rasterizing d>2 needs O(R^d) memory — see DESIGN.md",
                ds.dim
            )));
        }
        if resolution < 8 {
            return Err(AsnnError::Grid("resolution must be >= 8".into()));
        }
        if ds.is_empty() {
            return Err(AsnnError::Grid("cannot rasterize an empty dataset".into()));
        }
        let (mins, maxs) = ds.bounds();
        let geom = Geometry::new(resolution, [mins[0], mins[1]], [maxs[0], maxs[1]], padding)?;

        let mut cell_points: Vec<(u32, u32)> = Vec::with_capacity(ds.len());
        for i in 0..ds.len() {
            let p = ds.point(i);
            let (px, py) = geom.pixel_of(p[0], p[1]);
            cell_points.push((geom.cell_index(px, py), i as u32));
        }
        Self::from_parts(geom, ds.num_classes, cell_points, ds.labels.clone())
    }

    /// Assemble a grid from its primary data: geometry, `(cell,
    /// point_id)` assignments, and per-point labels. The derived state
    /// (count images, row prefix sums, sort order) is recomputed, so
    /// this is both the tail of [`build_padded`](Self::build_padded)
    /// and the snapshot-restore path ([`snapshot::from_bytes`]) — a
    /// restored grid is structurally identical to a rebuilt one.
    /// Inputs are fully validated (snapshot bytes are untrusted).
    pub(crate) fn from_parts(
        geom: Geometry,
        num_classes: usize,
        mut cell_points: Vec<(u32, u32)>,
        labels: Vec<u16>,
    ) -> Result<Self> {
        let r = geom.resolution();
        let n = cell_points.len();
        let cells = (r as u64) * (r as u64);
        if num_classes == 0 || num_classes > u16::MAX as usize + 1 {
            return Err(AsnnError::Grid(format!("invalid class count {num_classes}")));
        }
        if labels.len() != n {
            return Err(AsnnError::Grid(format!(
                "label count {} does not match point count {n}",
                labels.len()
            )));
        }
        let c = num_classes;
        let mut total = vec![0u16; r * r];
        let mut class_counts = vec![0u16; r * r * c];
        let mut seen = vec![0u64; n / 64 + 1];
        for (i, &(cell, pid)) in cell_points.iter().enumerate() {
            if (cell as u64) >= cells {
                return Err(AsnnError::Grid(format!(
                    "cell {cell} out of range for resolution {r} (entry {i})"
                )));
            }
            if pid as usize >= n {
                return Err(AsnnError::Grid(format!(
                    "point id {pid} out of range for {n} points (entry {i})"
                )));
            }
            let (word, bit) = (pid as usize / 64, pid as usize % 64);
            if seen[word] & (1 << bit) != 0 {
                return Err(AsnnError::Grid(format!("duplicate point id {pid} (entry {i})")));
            }
            seen[word] |= 1 << bit;
            let label = labels[pid as usize] as usize;
            if label >= c {
                return Err(AsnnError::Grid(format!(
                    "label {label} out of range for {c} classes (point {pid})"
                )));
            }
            total[cell as usize] = total[cell as usize].saturating_add(1);
            let ci = cell as usize * c + label;
            class_counts[ci] = class_counts[ci].saturating_add(1);
        }
        cell_points.sort_unstable();

        // per-row prefix sums over the total image (O(1) span counts)
        let mut row_prefix = vec![0u32; r * (r + 1)];
        for y in 0..r {
            let mut acc = 0u32;
            let base = y * (r + 1);
            for x in 0..r {
                acc += total[y * r + x] as u32;
                row_prefix[base + x + 1] = acc;
            }
        }

        Ok(Self {
            geom,
            num_classes: c,
            total,
            class_counts,
            cell_points,
            labels,
            row_prefix,
            n_points: n,
        })
    }

    pub fn geometry(&self) -> &Geometry {
        &self.geom
    }

    pub fn resolution(&self) -> usize {
        self.geom.resolution()
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    pub fn n_points(&self) -> usize {
        self.n_points
    }

    /// Total point count at pixel `(px, py)`.
    #[inline]
    pub fn count_at(&self, px: u32, py: u32) -> u16 {
        self.total[self.geom.cell_index(px, py) as usize]
    }

    /// Raw total-count image row (for the scan hot path).
    #[inline]
    pub fn total_row(&self, py: u32) -> &[u16] {
        let r = self.geom.resolution();
        &self.total[py as usize * r..(py as usize + 1) * r]
    }

    /// Full total-count image (row-major), e.g. for PJRT window crops.
    pub fn total_image(&self) -> &[u16] {
        &self.total
    }

    /// Per-class counts at a pixel, as a slice of length `num_classes`.
    #[inline]
    pub fn class_counts_at(&self, px: u32, py: u32) -> &[u16] {
        let base = self.geom.cell_index(px, py) as usize * self.num_classes;
        &self.class_counts[base..base + self.num_classes]
    }

    /// Point ids stored in a cell (empty slice if none).
    pub fn points_in_cell(&self, cell: u32) -> &[(u32, u32)] {
        let lo = self.cell_points.partition_point(|&(c, _)| c < cell);
        let hi = self.cell_points.partition_point(|&(c, _)| c <= cell);
        &self.cell_points[lo..hi]
    }

    /// All `(cell, point_id)` entries whose cell lies in the inclusive
    /// range `[cell0, cell1]` — one binary search pair per disk *row*
    /// instead of per pixel (cells in a row are contiguous).
    #[inline]
    pub fn points_in_cell_range(&self, cell0: u32, cell1: u32) -> &[(u32, u32)] {
        let lo = self.cell_points.partition_point(|&(c, _)| c < cell0);
        let hi = self.cell_points.partition_point(|&(c, _)| c <= cell1);
        &self.cell_points[lo..hi]
    }

    /// Label of a point id (copied from the dataset at build time).
    #[inline]
    pub fn label_of(&self, pid: u32) -> u16 {
        self.labels[pid as usize]
    }

    /// Points in row `py`, columns `[x0, x1]` inclusive — O(1) via the
    /// row prefix table.
    #[inline]
    pub fn row_span_count(&self, py: u32, x0: u32, x1: u32) -> u32 {
        debug_assert!(x0 <= x1);
        let r1 = self.geom.resolution() + 1;
        let base = py as usize * r1;
        self.row_prefix[base + x1 as usize + 1] - self.row_prefix[base + x0 as usize]
    }

    /// Point ids at pixel `(px, py)`.
    pub fn points_at(&self, px: u32, py: u32) -> impl Iterator<Item = u32> + '_ {
        self.points_in_cell(self.geom.cell_index(px, py))
            .iter()
            .map(|&(_, pid)| pid)
    }

    /// Number of distinct occupied cells.
    pub fn occupied_cells(&self) -> usize {
        let mut n = 0;
        let mut last = u32::MAX;
        for &(c, _) in &self.cell_points {
            if c != last {
                n += 1;
                last = c;
            }
        }
        n
    }

    /// Fraction of points that share a pixel with another point — the
    /// paper's §2 overlap/accuracy concern, quantified.
    pub fn overlap_fraction(&self) -> f64 {
        if self.n_points == 0 {
            return 0.0;
        }
        let mut overlapped = 0usize;
        let mut i = 0;
        while i < self.cell_points.len() {
            let cell = self.cell_points[i].0;
            let mut j = i + 1;
            while j < self.cell_points.len() && self.cell_points[j].0 == cell {
                j += 1;
            }
            if j - i > 1 {
                overlapped += j - i;
            }
            i = j;
        }
        overlapped as f64 / self.n_points as f64
    }

    /// Approximate resident memory of the index in bytes (the paper's
    /// resolution/memory trade-off, measured).
    pub fn memory_bytes(&self) -> usize {
        self.total.len() * 2
            + self.class_counts.len() * 2
            + self.cell_points.len() * 8
            + self.labels.len() * 2
            + self.row_prefix.len() * 4
    }

    /// Crop a `w × w` window of the total-count image centered at
    /// `(cx, cy)` into `out` as f32 (the PJRT artifact input layout).
    /// Out-of-image pixels are zero-filled.
    pub fn crop_total_f32(&self, cx: u32, cy: u32, w: usize, out: &mut [f32]) {
        assert_eq!(out.len(), w * w);
        out.fill(0.0);
        let r = self.geom.resolution() as i64;
        let half = (w / 2) as i64;
        let (cx, cy) = (cx as i64, cy as i64);
        for wy in 0..w as i64 {
            let gy = cy - half + wy;
            if gy < 0 || gy >= r {
                continue;
            }
            let x0 = (cx - half).max(0);
            let x1 = (cx - half + w as i64).min(r);
            if x0 >= x1 {
                continue;
            }
            let src0 = (gy * r + x0) as usize;
            let dst0 = (wy * w as i64 + (x0 - (cx - half))) as usize;
            for (dst, src) in (dst0..).zip(src0..(src0 + (x1 - x0) as usize)) {
                out[dst] = self.total[src] as f32;
            }
        }
    }

    /// Same as [`crop_total_f32`](Self::crop_total_f32) but for the
    /// per-class images: `out` has layout `[C, w, w]`.
    pub fn crop_classes_f32(&self, cx: u32, cy: u32, w: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.num_classes * w * w);
        out.fill(0.0);
        let r = self.geom.resolution() as i64;
        let half = (w / 2) as i64;
        let (cx, cy) = (cx as i64, cy as i64);
        let c = self.num_classes;
        for wy in 0..w as i64 {
            let gy = cy - half + wy;
            if gy < 0 || gy >= r {
                continue;
            }
            for wx in 0..w as i64 {
                let gx = cx - half + wx;
                if gx < 0 || gx >= r {
                    continue;
                }
                let base = ((gy * r + gx) as usize) * c;
                for ci in 0..c {
                    let v = self.class_counts[base + ci];
                    if v != 0 {
                        out[ci * w * w + (wy as usize) * w + wx as usize] = v as f32;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn grid(n: usize, res: usize) -> (Dataset, MultiGrid) {
        let ds = generate(&SyntheticSpec::paper_default(n, 7));
        let g = MultiGrid::build(&ds, res).unwrap();
        (ds, g)
    }

    #[test]
    fn counts_sum_to_n() {
        let (ds, g) = grid(2000, 128);
        let total: u64 = g.total.iter().map(|&v| v as u64).sum();
        assert_eq!(total, ds.len() as u64);
        let class_total: u64 = g.class_counts.iter().map(|&v| v as u64).sum();
        assert_eq!(class_total, ds.len() as u64);
    }

    #[test]
    fn per_class_matches_total() {
        let (_, g) = grid(2000, 128);
        for py in 0..128u32 {
            for px in 0..128u32 {
                let t = g.count_at(px, py) as u32;
                let c: u32 = g.class_counts_at(px, py).iter().map(|&v| v as u32).sum();
                assert_eq!(t, c);
            }
        }
    }

    #[test]
    fn bucket_lookup_recovers_all_points() {
        let (ds, g) = grid(500, 64);
        let mut recovered = 0;
        for py in 0..64u32 {
            for px in 0..64u32 {
                recovered += g.points_at(px, py).count();
            }
        }
        assert_eq!(recovered, ds.len());
    }

    #[test]
    fn points_map_to_their_own_pixel() {
        let (ds, g) = grid(300, 256);
        for i in 0..ds.len() {
            let p = ds.point(i);
            let (px, py) = g.geometry().pixel_of(p[0], p[1]);
            assert!(g.points_at(px, py).any(|pid| pid as usize == i));
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        let ds3 = crate::data::Dataset::new(3, vec![0.0; 9], vec![0, 0, 0], 1).unwrap();
        assert!(MultiGrid::build(&ds3, 64).is_err());
        let ds = generate(&SyntheticSpec::paper_default(10, 1));
        assert!(MultiGrid::build(&ds, 4).is_err());
    }

    #[test]
    fn overlap_decreases_with_resolution() {
        let ds = generate(&SyntheticSpec::paper_default(5000, 3));
        let low = MultiGrid::build(&ds, 64).unwrap().overlap_fraction();
        let high = MultiGrid::build(&ds, 2048).unwrap().overlap_fraction();
        assert!(low > high, "low={low} high={high}");
        assert!(high < 0.05);
    }

    #[test]
    fn crop_total_center_and_edges() {
        let (_, g) = grid(1000, 128);
        let w = 16;
        let mut out = vec![0f32; w * w];
        g.crop_total_f32(64, 64, w, &mut out);
        // window sum equals direct pixel sum
        let mut direct = 0f32;
        for wy in 0..w as u32 {
            for wx in 0..w as u32 {
                direct += g.count_at(64 - 8 + wx, 64 - 8 + wy) as f32;
            }
        }
        assert_eq!(out.iter().sum::<f32>(), direct);
        // corner crop zero-fills out-of-image area without panicking
        g.crop_total_f32(0, 0, w, &mut out);
        assert!(out.iter().sum::<f32>() >= 0.0);
    }

    #[test]
    fn crop_classes_layout() {
        let (_, g) = grid(1000, 128);
        let w = 8;
        let mut per_class = vec![0f32; 3 * w * w];
        let mut total = vec![0f32; w * w];
        g.crop_classes_f32(40, 40, w, &mut per_class);
        g.crop_total_f32(40, 40, w, &mut total);
        for i in 0..w * w {
            let s: f32 = (0..3).map(|c| per_class[c * w * w + i]).sum();
            assert_eq!(s, total[i]);
        }
    }

    #[test]
    fn memory_accounting_scales_with_resolution() {
        let ds = generate(&SyntheticSpec::paper_default(1000, 5));
        let small = MultiGrid::build(&ds, 64).unwrap().memory_bytes();
        let big = MultiGrid::build(&ds, 512).unwrap().memory_bytes();
        assert!(big > small * 16, "small={small} big={big}");
    }
}
