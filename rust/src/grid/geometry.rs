//! Mapping between data space and pixel space.
//!
//! The paper transforms points (and each query) "onto the same image".
//! [`Geometry`] owns that affine map: data bounding box (optionally
//! padded) → `resolution × resolution` pixels.

use crate::error::{AsnnError, Result};

/// Affine data-space ↔ pixel-space mapping for a square image.
#[derive(Debug, Clone, PartialEq)]
pub struct Geometry {
    resolution: usize,
    mins: [f64; 2],
    maxs: [f64; 2],
    /// Pixels per data unit, per axis.
    scale: [f64; 2],
}

impl Geometry {
    /// Build from data bounds with fractional `padding` (0.05 = 5 % of
    /// the box added on every side). Degenerate axes (all points equal)
    /// get a unit extent so the map stays invertible.
    pub fn new(resolution: usize, mins: [f64; 2], maxs: [f64; 2], padding: f64) -> Result<Self> {
        if resolution < 2 {
            return Err(AsnnError::Grid("resolution must be >= 2".into()));
        }
        if !(0.0..0.5).contains(&padding) {
            return Err(AsnnError::Grid("padding must be in [0, 0.5)".into()));
        }
        let mut lo = [0.0; 2];
        let mut hi = [0.0; 2];
        for d in 0..2 {
            if !(mins[d].is_finite() && maxs[d].is_finite()) || mins[d] > maxs[d] {
                return Err(AsnnError::Grid(format!(
                    "invalid bounds on axis {d}: [{}, {}]",
                    mins[d], maxs[d]
                )));
            }
            let extent = (maxs[d] - mins[d]).max(f64::MIN_POSITIVE);
            let extent = if extent <= f64::MIN_POSITIVE { 1.0 } else { extent };
            let pad = extent * padding;
            lo[d] = mins[d] - pad;
            hi[d] = maxs[d] + pad;
        }
        let scale = [
            resolution as f64 / (hi[0] - lo[0]),
            resolution as f64 / (hi[1] - lo[1]),
        ];
        Ok(Self { resolution, mins: lo, maxs: hi, scale })
    }

    pub fn resolution(&self) -> usize {
        self.resolution
    }

    pub fn bounds(&self) -> ([f64; 2], [f64; 2]) {
        (self.mins, self.maxs)
    }

    /// Side length of one pixel in data units (per axis).
    pub fn pixel_size(&self) -> [f64; 2] {
        [1.0 / self.scale[0], 1.0 / self.scale[1]]
    }

    /// Map a data-space point to its pixel. Points outside the bounds
    /// clamp to the border pixel (the paper does not specify behaviour
    /// for out-of-hull queries; clamping keeps the scan well-defined).
    #[inline]
    pub fn pixel_of(&self, x: f64, y: f64) -> (u32, u32) {
        let px = ((x - self.mins[0]) * self.scale[0]).floor();
        let py = ((y - self.mins[1]) * self.scale[1]).floor();
        let max = (self.resolution - 1) as f64;
        (px.clamp(0.0, max) as u32, py.clamp(0.0, max) as u32)
    }

    /// Row-major cell index of a pixel.
    #[inline]
    pub fn cell_index(&self, px: u32, py: u32) -> u32 {
        py * self.resolution as u32 + px
    }

    /// Inverse of [`cell_index`](Self::cell_index).
    #[inline]
    pub fn cell_to_pixel(&self, cell: u32) -> (u32, u32) {
        let r = self.resolution as u32;
        (cell % r, cell / r)
    }

    /// Data-space center of a pixel.
    #[inline]
    pub fn center_of(&self, px: u32, py: u32) -> (f64, f64) {
        (
            self.mins[0] + (px as f64 + 0.5) / self.scale[0],
            self.mins[1] + (py as f64 + 0.5) / self.scale[1],
        )
    }

    /// Convert a data-space length on axis 0 to pixels (used to map the
    /// paper's pixel radius to data space and back).
    #[inline]
    pub fn len_to_pixels(&self, len: f64) -> f64 {
        len * self.scale[0]
    }

    /// Convert a pixel count to a data-space length on axis 0.
    #[inline]
    pub fn pixels_to_len(&self, px: f64) -> f64 {
        px / self.scale[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(res: usize) -> Geometry {
        Geometry::new(res, [0.0, 0.0], [1.0, 1.0], 0.0).unwrap()
    }

    #[test]
    fn corners_map_to_corner_pixels() {
        let g = unit(100);
        assert_eq!(g.pixel_of(0.0, 0.0), (0, 0));
        assert_eq!(g.pixel_of(1.0, 1.0), (99, 99)); // max clamps to last pixel
        assert_eq!(g.pixel_of(0.999, 0.0), (99, 0));
    }

    #[test]
    fn out_of_bounds_clamps() {
        let g = unit(10);
        assert_eq!(g.pixel_of(-5.0, 0.5), (0, 5));
        assert_eq!(g.pixel_of(2.0, 0.5), (9, 5));
    }

    #[test]
    fn cell_roundtrip() {
        let g = unit(37);
        for &(px, py) in &[(0, 0), (36, 36), (5, 20), (20, 5)] {
            assert_eq!(g.cell_to_pixel(g.cell_index(px, py)), (px, py));
        }
    }

    #[test]
    fn center_is_inside_pixel() {
        let g = unit(10);
        let (cx, cy) = g.center_of(3, 7);
        assert_eq!(g.pixel_of(cx, cy), (3, 7));
    }

    #[test]
    fn padding_expands_bounds() {
        let g = Geometry::new(100, [0.0, 0.0], [1.0, 1.0], 0.1).unwrap();
        let (mins, maxs) = g.bounds();
        assert!(mins[0] < 0.0 && maxs[0] > 1.0);
        // padded geometry keeps interior points interior
        let (px, py) = g.pixel_of(0.0, 0.0);
        assert!(px > 0 && py > 0);
    }

    #[test]
    fn degenerate_axis_handled() {
        let g = Geometry::new(16, [0.5, 0.0], [0.5, 1.0], 0.0).unwrap();
        let (px, _) = g.pixel_of(0.5, 0.5);
        assert!(px < 16);
    }

    #[test]
    fn invalid_bounds_rejected() {
        assert!(Geometry::new(16, [1.0, 0.0], [0.0, 1.0], 0.0).is_err());
        assert!(Geometry::new(16, [f64::NAN, 0.0], [1.0, 1.0], 0.0).is_err());
        assert!(Geometry::new(1, [0.0, 0.0], [1.0, 1.0], 0.0).is_err());
        assert!(Geometry::new(16, [0.0, 0.0], [1.0, 1.0], 0.9).is_err());
    }

    #[test]
    fn length_conversions_invert() {
        let g = unit(200);
        let px = g.len_to_pixels(0.25);
        assert!((px - 50.0).abs() < 1e-9);
        assert!((g.pixels_to_len(px) - 0.25).abs() < 1e-12);
    }
}
