//! Fault-injection wrapper engine for resilience testing.
//!
//! [`ChaosEngine`] implements [`NnEngine`] by delegating to an inner
//! engine after (deterministically, seeded via [`crate::util::rng`])
//! injecting configurable latency, errors, and panics. The coordinator
//! chaos tests register it like any other engine and drive the real
//! server through it, so panic isolation, deadlines, circuit breakers,
//! and fallback are all exercised end-to-end rather than mocked.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::{EngineInfo, Neighbor, NnEngine, QueryStats};
use crate::error::{AsnnError, Result};
use crate::obs::SearchTrace;
use crate::util::rng::Rng;

/// Injection probabilities and shape. Rates are independent per call:
/// latency is applied first (so a slow call can also fail), then panic,
/// then error.
#[derive(Debug, Clone, Copy)]
pub struct ChaosConfig {
    /// Probability of returning `AsnnError::Runtime` per call.
    pub error_rate: f64,
    /// Probability of panicking per call.
    pub panic_rate: f64,
    /// Probability of sleeping `latency` before proceeding.
    pub latency_rate: f64,
    pub latency: Duration,
    /// Flapping: alternate sick/healthy windows of this many *calls*
    /// (deterministic, unlike wall-clock flapping). Calls 0..p fail,
    /// p..2p succeed, and so on. 0 = off. Checked before the rate rolls;
    /// a sick-window failure counts as an injected error.
    pub flap_period: u64,
    pub seed: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            error_rate: 0.0,
            panic_rate: 0.0,
            latency_rate: 0.0,
            latency: Duration::from_millis(50),
            flap_period: 0,
            seed: 0xC4A05,
        }
    }
}

/// Counters of what was actually injected (for assertions).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChaosCounts {
    pub errors: u64,
    pub panics: u64,
    pub delays: u64,
}

/// An [`NnEngine`] that misbehaves on purpose.
pub struct ChaosEngine {
    inner: Arc<dyn NnEngine>,
    cfg: ChaosConfig,
    rng: Mutex<Rng>,
    calls: AtomicU64,
    errors: AtomicU64,
    panics: AtomicU64,
    delays: AtomicU64,
}

impl ChaosEngine {
    pub fn new(inner: Arc<dyn NnEngine>, cfg: ChaosConfig) -> Self {
        Self {
            inner,
            cfg,
            rng: Mutex::new(Rng::new(cfg.seed)),
            calls: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            delays: AtomicU64::new(0),
        }
    }

    /// Every call fails with a runtime error.
    pub fn failing(inner: Arc<dyn NnEngine>, seed: u64) -> Self {
        Self::new(inner, ChaosConfig { error_rate: 1.0, seed, ..ChaosConfig::default() })
    }

    /// Every call panics.
    pub fn panicking(inner: Arc<dyn NnEngine>, seed: u64) -> Self {
        Self::new(inner, ChaosConfig { panic_rate: 1.0, seed, ..ChaosConfig::default() })
    }

    /// Every call sleeps `latency` first.
    pub fn slow(inner: Arc<dyn NnEngine>, latency: Duration, seed: u64) -> Self {
        Self::new(
            inner,
            ChaosConfig { latency_rate: 1.0, latency, seed, ..ChaosConfig::default() },
        )
    }

    /// Alternates sick and healthy windows of `period` calls each:
    /// calls 0..period fail, period..2·period succeed, and so on.
    /// Deterministic in call count, so tests can script exactly which
    /// breaker probes land in which window.
    pub fn flapping(inner: Arc<dyn NnEngine>, period: u64, seed: u64) -> Self {
        Self::new(inner, ChaosConfig { flap_period: period, seed, ..ChaosConfig::default() })
    }

    pub fn counts(&self) -> ChaosCounts {
        ChaosCounts {
            errors: self.errors.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            delays: self.delays.load(Ordering::Relaxed),
        }
    }

    /// Roll the dice once; sleep, panic, or error per config. The rng
    /// lock is released before sleeping/panicking so a stuck or
    /// unwinding call never poisons other callers.
    fn inject(&self) -> Result<()> {
        if self.cfg.flap_period > 0 {
            let call = self.calls.fetch_add(1, Ordering::SeqCst);
            if (call / self.cfg.flap_period) % 2 == 0 {
                self.errors.fetch_add(1, Ordering::Relaxed);
                return Err(AsnnError::Runtime(format!(
                    "chaos: flapping sick window (call {call})"
                )));
            }
        }
        let (delay_roll, panic_roll, error_roll) = {
            let mut rng = self.rng.lock().unwrap_or_else(|p| p.into_inner());
            (rng.next_f64(), rng.next_f64(), rng.next_f64())
        };
        if delay_roll < self.cfg.latency_rate {
            self.delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.cfg.latency);
        }
        if panic_roll < self.cfg.panic_rate {
            self.panics.fetch_add(1, Ordering::Relaxed);
            panic!("chaos: injected panic");
        }
        if error_roll < self.cfg.error_rate {
            self.errors.fetch_add(1, Ordering::Relaxed);
            return Err(AsnnError::Runtime("chaos: injected engine fault".into()));
        }
        Ok(())
    }
}

impl NnEngine for ChaosEngine {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn info(&self) -> EngineInfo {
        // Identity is its own (breakers must key on the wrapper), but
        // capabilities are whatever the wrapped engine can do.
        EngineInfo { name: self.name(), ..self.inner.info() }
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn knn(&self, q: &[f64], k: usize) -> Result<Vec<Neighbor>> {
        self.inject()?;
        self.inner.knn(q, k)
    }

    fn knn_stats(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, QueryStats)> {
        self.inject()?;
        self.inner.knn_stats(q, k)
    }

    fn knn_trace(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, SearchTrace)> {
        self.inject()?;
        self.inner.knn_trace(q, k)
    }

    fn classify(&self, q: &[f64], k: usize) -> Result<u16> {
        self.inject()?;
        self.inner.classify(q, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::engine::brute::BruteEngine;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn inner() -> Arc<dyn NnEngine> {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(500, 71)));
        Arc::new(BruteEngine::new(ds))
    }

    #[test]
    fn zero_rates_are_transparent() {
        let base = inner();
        let chaos = ChaosEngine::new(Arc::clone(&base), ChaosConfig::default());
        let a = chaos.knn(&[0.5, 0.5], 7).unwrap();
        let b = base.knn(&[0.5, 0.5], 7).unwrap();
        assert_eq!(a.len(), 7);
        let ids = |v: &[Neighbor]| v.iter().map(|n| n.id).collect::<Vec<_>>();
        assert_eq!(ids(&a), ids(&b));
        assert_eq!(chaos.counts(), ChaosCounts::default());
    }

    #[test]
    fn failing_always_errors_with_runtime_tag() {
        let chaos = ChaosEngine::failing(inner(), 1);
        for _ in 0..5 {
            match chaos.knn(&[0.5, 0.5], 3) {
                Err(e) => assert_eq!(e.tag(), "runtime"),
                Ok(_) => panic!("expected injected error"),
            }
        }
        assert_eq!(chaos.counts().errors, 5);
    }

    #[test]
    fn panicking_panics_and_counts() {
        let chaos = ChaosEngine::panicking(inner(), 2);
        let r = catch_unwind(AssertUnwindSafe(|| chaos.knn(&[0.5, 0.5], 3)));
        assert!(r.is_err());
        assert_eq!(chaos.counts().panics, 1);
        // rng lock was released before the panic: next call still rolls
        let r2 = catch_unwind(AssertUnwindSafe(|| chaos.classify(&[0.5, 0.5], 3)));
        assert!(r2.is_err());
        assert_eq!(chaos.counts().panics, 2);
    }

    #[test]
    fn slow_injects_latency() {
        let chaos = ChaosEngine::slow(inner(), Duration::from_millis(30), 3);
        let t = std::time::Instant::now();
        chaos.knn(&[0.5, 0.5], 3).unwrap();
        assert!(t.elapsed() >= Duration::from_millis(25), "{:?}", t.elapsed());
        assert_eq!(chaos.counts().delays, 1);
    }

    #[test]
    fn flapping_alternates_sick_and_healthy_windows() {
        let chaos = ChaosEngine::flapping(inner(), 3, 4);
        let outcomes: Vec<bool> =
            (0..12).map(|_| chaos.knn(&[0.5, 0.5], 3).is_ok()).collect();
        assert_eq!(
            outcomes,
            vec![
                false, false, false, // calls 0..3: sick
                true, true, true, // 3..6: healthy
                false, false, false, // 6..9: sick again
                true, true, true,
            ]
        );
        assert_eq!(chaos.counts().errors, 6);
    }

    #[test]
    fn injection_sequence_is_deterministic_per_seed() {
        let mk = |seed| {
            ChaosEngine::new(
                inner(),
                ChaosConfig { error_rate: 0.5, seed, ..ChaosConfig::default() },
            )
        };
        let outcomes = |e: &ChaosEngine| {
            (0..32).map(|_| e.knn(&[0.5, 0.5], 3).is_ok()).collect::<Vec<_>>()
        };
        let (a, b) = (mk(42), mk(42));
        assert_eq!(outcomes(&a), outcomes(&b));
        let c = mk(43);
        assert_ne!(outcomes(&a), outcomes(&c)); // overwhelmingly likely
    }
}
