//! The paper's active-search engine (pure rust reference path).
//!
//! Algorithm (paper §2): map the query onto the count image, scan the
//! pixels inside a circle of radius `r` around it, and update `r` by
//! Eq. 1 until the circle holds exactly `k` points; those points are
//! the answer. Per-class count images provide classification votes.
//!
//! Production extensions (all off by default or faithful to the paper):
//! tolerance/oscillation handling ([`crate::active::radius`]),
//! `refined` mode (exact re-rank of the final circle via the grid's
//! point buckets), and a density-informed r₀ policy (ABL-R0).

use std::cell::RefCell;
use std::sync::Arc;

use super::{EngineInfo, Neighbor, NnEngine, QueryStats, TopK};
use crate::active::radius::{RadiusPolicy, Step};
use crate::active::scan;
use crate::active::{SearchStep, SearchTrace};
use crate::config::{Metric, R0Policy, SearchMode};
use crate::data::soa::SoaMirror;
use crate::data::Dataset;
use crate::error::{AsnnError, Result};
use crate::grid::{MultiGrid, Pyramid};
use crate::obs::{Recorder, Stage};
use crate::util::timer::Timer;

/// Tuning for the active engine. Defaults are the paper's §3 setup.
#[derive(Debug, Clone)]
pub struct ActiveParams {
    pub r0: u32,
    pub max_iters: u32,
    pub metric: Metric,
    pub mode: SearchMode,
    pub r0_policy: R0Policy,
    pub tolerance: u32,
    /// Coarse-to-fine radius fast-forward: before paying for any exact
    /// O(r) disk scan, grow `r` while a pyramid upper bound proves the
    /// circle cannot yet hold k points. Off by default (the paper's
    /// loop measures every radius).
    pub coarse_skip: bool,
}

impl Default for ActiveParams {
    fn default() -> Self {
        Self {
            r0: 100,
            max_iters: 64,
            metric: Metric::L2,
            mode: SearchMode::Approx,
            r0_policy: R0Policy::Fixed,
            tolerance: 0,
            coarse_skip: false,
        }
    }
}

/// Result of the radius-adaptation loop: the final circle.
#[derive(Debug, Clone)]
pub struct FinalCircle {
    pub cx: u32,
    pub cy: u32,
    pub r: u32,
    pub n_inside: u64,
    pub trace: SearchTrace,
}

/// The paper's engine over a [`MultiGrid`] index.
pub struct ActiveEngine {
    grid: MultiGrid,
    data: Option<Arc<Dataset>>,
    pyramid: Option<Pyramid>,
    /// Blocked SoA f32 mirror driving the refined-mode distance kernel
    /// (built only when the dataset is present and mode is `Refined`).
    soa: Option<SoaMirror>,
    params: ActiveParams,
    /// Stage telemetry sink. When attached, every query's
    /// coarse/scan/refine wall-clock goes into the shared recorder;
    /// when absent the hot path takes no timestamps at all.
    recorder: Option<Arc<Recorder>>,
}

/// Per-thread query scratch: every buffer the hot path needs, reusable
/// across the queries of a batch. `const`-constructible so it can live
/// in a `thread_local!` slot on the coordinator's long-lived workers —
/// after warm-up, a query allocates nothing but its returned hits.
struct Scratch {
    cands: Vec<scan::Candidate>,
    ids: Vec<u32>,
    dists: Vec<f32>,
    counts: Vec<u64>,
    top: TopK,
}

impl Scratch {
    const fn new() -> Self {
        Self {
            cands: Vec::new(),
            ids: Vec::new(),
            dists: Vec::new(),
            counts: Vec::new(),
            top: TopK::empty(),
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = const { RefCell::new(Scratch::new()) };
}

impl ActiveEngine {
    /// Build the index from a dataset (keeps the dataset for labels and
    /// `refined`-mode exact distances).
    pub fn new(data: Arc<Dataset>, resolution: usize, params: ActiveParams) -> Result<Self> {
        let grid = MultiGrid::build(&data, resolution)?;
        Ok(Self::assemble(grid, Some(data), params))
    }

    /// Build from an existing grid; `refined` mode and true labels are
    /// unavailable without the dataset (neighbors carry label 0).
    pub fn from_grid(grid: MultiGrid, params: ActiveParams) -> Self {
        Self::assemble(grid, None, params)
    }

    /// Reattach a restored grid snapshot to its dataset (warm boot):
    /// unlike [`from_grid`](Self::from_grid), `refined` mode and true
    /// labels stay available. The pair is validated — a mismatched
    /// grid/dataset generation is rejected rather than served.
    pub fn restore(grid: MultiGrid, data: Arc<Dataset>, params: ActiveParams) -> Result<Self> {
        if data.dim != 2 {
            return Err(AsnnError::Grid(format!(
                "restored dataset has dim {} (grid is 2-D)",
                data.dim
            )));
        }
        if grid.n_points() != data.len() || grid.num_classes() != data.num_classes {
            return Err(AsnnError::Grid(format!(
                "grid snapshot ({} points, {} classes) does not match dataset \
                 ({} points, {} classes)",
                grid.n_points(),
                grid.num_classes(),
                data.len(),
                data.num_classes
            )));
        }
        Ok(Self::assemble(grid, Some(data), params))
    }

    fn assemble(grid: MultiGrid, data: Option<Arc<Dataset>>, params: ActiveParams) -> Self {
        let pyramid = if params.r0_policy == R0Policy::Density || params.coarse_skip {
            Some(Pyramid::build(&grid))
        } else {
            None
        };
        let soa = match (&data, params.mode) {
            (Some(ds), SearchMode::Refined) => Some(SoaMirror::build(ds)),
            _ => None,
        };
        Self { grid, data, pyramid, soa, params, recorder: None }
    }

    /// Attach the shared observability recorder. Call before the engine
    /// is wrapped in an `Arc` and registered with the router.
    pub fn set_recorder(&mut self, recorder: Arc<Recorder>) {
        self.recorder = Some(recorder);
    }

    pub fn grid(&self) -> &MultiGrid {
        &self.grid
    }

    pub fn params(&self) -> &ActiveParams {
        &self.params
    }

    /// The backing dataset, when the engine was built with one.
    pub fn dataset(&self) -> &Option<Arc<Dataset>> {
        &self.data
    }

    /// Image-diagonal radius cap (covers the whole image from anywhere).
    fn r_max(&self) -> u32 {
        let r = self.grid.resolution() as f64;
        (r * std::f64::consts::SQRT_2).ceil() as u32
    }

    fn initial_radius(&self, px: u32, py: u32, k: usize) -> u32 {
        match self.params.r0_policy {
            R0Policy::Fixed => self.params.r0,
            R0Policy::Density => self
                .pyramid
                .as_ref()
                .map(|p| p.suggest_r0(k, px, py))
                .unwrap_or(self.params.r0),
        }
    }

    /// Run the radius-adaptation loop for a query point; the core of
    /// the paper's algorithm. Public for Fig. 2 traces and the PJRT
    /// engine (which shares this loop, swapping the count primitive).
    pub fn search(&self, q: &[f64], k: usize) -> Result<FinalCircle> {
        self.search_with(q, k, |cx, cy, r| {
            scan::count_in_disk(&self.grid, cx, cy, r, self.params.metric)
        })
    }

    /// [`search`](Self::search) with a caller-provided count primitive
    /// (`|cx, cy, r| -> points inside`).
    pub fn search_with(
        &self,
        q: &[f64],
        k: usize,
        mut count: impl FnMut(u32, u32, u32) -> u64,
    ) -> Result<FinalCircle> {
        self.check(q, k)?;
        let geom = self.grid.geometry();
        let (cx, cy) = geom.pixel_of(q[0], q[1]);
        let mut r = self.initial_radius(cx, cy, k).max(1);
        let r_max = self.r_max();
        let mut policy = RadiusPolicy::new(k, self.params.tolerance, self.params.max_iters, r_max);
        let mut trace = SearchTrace::default();
        // Coarse-to-fine fast-forward: while even a pyramid *upper*
        // bound on the disk count falls short of k, the exact O(r) scan
        // below cannot succeed either, so grow r by Eq. 1 against the
        // bound — each skipped radius costs O(r / 2^level) row sums
        // instead of a full scan, and never appears in `trace.steps`.
        if let Some(pyr) = self.pyramid.as_ref().filter(|_| self.params.coarse_skip) {
            let level = (pyr.num_levels() - 1).min(2);
            while trace.coarse_skips < self.params.max_iters && r < r_max {
                let bound = pyr.count_in_disk_bound(level, cx, cy, r, self.params.metric);
                if bound >= k as u64 {
                    break;
                }
                let next = RadiusPolicy::eq1(r, k as u64, bound.max(1)).max(r + 1);
                r = next.min(r_max);
                trace.coarse_skips += 1;
            }
        }
        loop {
            let n = count(cx, cy, r);
            trace.steps.push(SearchStep { r, n });
            match policy.step(r, n) {
                Step::Done => {
                    trace.converged = true;
                    return Ok(FinalCircle { cx, cy, r, n_inside: n, trace });
                }
                Step::Settle(rs) => {
                    // settle on the ≥k bracket side; recount if it is
                    // not the circle we just measured
                    let n_final = if rs == r { n } else { count(cx, cy, rs) };
                    trace.converged = true;
                    if rs != r {
                        trace.steps.push(SearchStep { r: rs, n: n_final });
                    }
                    return Ok(FinalCircle { cx, cy, r: rs, n_inside: n_final, trace });
                }
                Step::Continue(next) => r = next,
                Step::Exhausted => {
                    trace.converged = false;
                    return Ok(FinalCircle { cx, cy, r, n_inside: n, trace });
                }
            }
        }
    }

    fn label_of(&self, pid: u32) -> u16 {
        self.data.as_ref().map(|d| d.label(pid as usize)).unwrap_or(0)
    }

    /// One query through a caller-owned [`Scratch`] — the shared body
    /// of `knn_stats`, `knn_trace`, and `knn_batch`. Candidates stream
    /// through the bounded [`TopK`] heap (no full sort, no truncate);
    /// refined mode runs the SoA f32 kernel over the candidate ids and
    /// defers the square root to the k survivors.
    ///
    /// With `timed` set, the three pipeline stages (coarse radius loop,
    /// disk scan, re-rank) are wall-clocked into the returned trace's
    /// spans and fed to the attached recorder; untimed queries skip the
    /// clock reads entirely so the batched hot path stays bare.
    fn query_scratch(
        &self,
        q: &[f64],
        k: usize,
        s: &mut Scratch,
        timed: bool,
    ) -> Result<(Vec<Neighbor>, QueryStats, SearchTrace)> {
        #[inline]
        fn tick(timed: bool) -> Option<Timer> {
            timed.then(Timer::new)
        }
        let t_coarse = tick(timed);
        let circle = self.search(q, k)?;
        let coarse_ns = t_coarse.map(|t| t.elapsed_ns());
        let t_scan = tick(timed);
        scan::collect_in_disk_into(
            &self.grid,
            circle.cx,
            circle.cy,
            circle.r,
            self.params.metric,
            &mut s.cands,
        );
        let scan_ns = t_scan.map(|t| t.elapsed_ns());
        let t_refine = tick(timed);
        let px_len = self.grid.geometry().pixel_size()[0];
        s.top.reset(k);
        let squared = match self.params.mode {
            SearchMode::Approx => {
                for c in &s.cands {
                    let dist = match self.params.metric {
                        Metric::L2 => c.pixel_dist.sqrt() * px_len,
                        Metric::L1 => c.pixel_dist * px_len,
                    };
                    if dist < s.top.worst() {
                        let label = self.label_of(c.point_id);
                        s.top.push(Neighbor { id: c.point_id, dist, label });
                    }
                }
                false
            }
            SearchMode::Refined => {
                let data = self.data.as_ref().ok_or_else(|| {
                    AsnnError::Query(
                        "refined mode requires the dataset (build with ActiveEngine::new)".into(),
                    )
                })?;
                let soa = self.soa.as_ref().expect("SoA mirror exists whenever data does");
                s.ids.clear();
                s.ids.extend(s.cands.iter().map(|c| c.point_id));
                let qf = [q[0] as f32, q[1] as f32];
                soa.dist2_ids_into(&s.ids, &qf, &mut s.dists);
                for (&id, &d2) in s.ids.iter().zip(s.dists.iter()) {
                    let d2 = d2 as f64;
                    if d2 < s.top.worst() {
                        s.top.push(Neighbor { id, dist: d2, label: data.label(id as usize) });
                    }
                }
                true
            }
        };
        let mut out = s.top.drain_sorted();
        if squared {
            for h in &mut out {
                h.dist = h.dist.sqrt();
            }
        }
        let mut trace = circle.trace;
        if let Some(ns) = coarse_ns {
            trace.push_span(Stage::Coarse, ns);
        }
        if let Some(ns) = scan_ns {
            trace.push_span(Stage::Scan, ns);
        }
        if let Some(t) = t_refine {
            trace.push_span(Stage::Refine, t.elapsed_ns());
        }
        if let Some(rec) = &self.recorder {
            for span in &trace.spans {
                rec.record_stage(span.stage, span.dur_ns);
            }
        }
        let work: u64 =
            trace.steps.iter().map(|st| scan::disk_pixels(st.r, self.params.metric)).sum();
        let stats = QueryStats {
            work,
            iterations: trace.iterations() as u32,
            converged: trace.converged,
        };
        Ok((out, stats, trace))
    }

    fn check(&self, q: &[f64], k: usize) -> Result<()> {
        if q.len() != 2 {
            return Err(AsnnError::Query(format!(
                "active engine requires 2-D queries (got dim {})",
                q.len()
            )));
        }
        if k == 0 || k > self.grid.n_points() {
            return Err(AsnnError::Query(format!(
                "k = {k} out of range for {} points",
                self.grid.n_points()
            )));
        }
        Ok(())
    }
}

impl NnEngine for ActiveEngine {
    fn name(&self) -> &'static str {
        "active"
    }

    fn info(&self) -> EngineInfo {
        EngineInfo { name: self.name(), supports_batch: true, supports_trace: true }
    }

    fn len(&self) -> usize {
        self.grid.n_points()
    }

    fn knn(&self, q: &[f64], k: usize) -> Result<Vec<Neighbor>> {
        Ok(self.knn_stats(q, k)?.0)
    }

    fn knn_stats(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, QueryStats)> {
        let timed = self.recorder.is_some();
        SCRATCH.with(|s| {
            self.query_scratch(q, k, &mut s.borrow_mut(), timed)
                .map(|(hits, stats, _)| (hits, stats))
        })
    }

    /// Real per-stage tracing: the coarse radius loop, the disk scan,
    /// and the re-rank each get a wall-clock span, alongside the radius
    /// schedule in `steps`.
    fn knn_trace(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, SearchTrace)> {
        SCRATCH.with(|s| {
            self.query_scratch(q, k, &mut s.borrow_mut(), true)
                .map(|(hits, _, trace)| (hits, trace))
        })
    }

    /// Batched kNN: borrow this worker's scratch once for the whole
    /// batch — candidate, id, distance, and heap buffers are reused
    /// across every query in it.
    fn knn_batch(&self, queries: &[&[f64]], k: usize) -> Vec<Result<Vec<Neighbor>>> {
        let timed = self.recorder.is_some();
        SCRATCH.with(|s| {
            let s = &mut *s.borrow_mut();
            queries
                .iter()
                .map(|q| self.query_scratch(q, k, s, timed).map(|(hits, _, _)| hits))
                .collect()
        })
    }

    /// The paper's classification: per-class counts inside the final
    /// circle (one count image per class), argmax vote.
    fn classify(&self, q: &[f64], k: usize) -> Result<u16> {
        let circle = self.search(q, k)?;
        SCRATCH.with(|s| {
            let s = &mut *s.borrow_mut();
            s.counts.clear();
            s.counts.resize(self.grid.num_classes(), 0);
            scan::class_counts_in_disk(
                &self.grid,
                circle.cx,
                circle.cy,
                circle.r,
                self.params.metric,
                &mut s.counts,
            );
            let best = s
                .counts
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
                .map(|(c, _)| c as u16)
                .unwrap_or(0);
            Ok(best)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, generate_queries, SyntheticSpec};
    use crate::engine::brute::BruteEngine;

    fn engine(n: usize, res: usize, params: ActiveParams) -> ActiveEngine {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(n, 55)));
        ActiveEngine::new(ds, res, params).unwrap()
    }

    #[test]
    fn returns_k_neighbors_when_converged() {
        let e = engine(20_000, 1000, ActiveParams::default());
        for q in generate_queries(10, 2, 56) {
            let (hits, st) = e.knn_stats(&q, 11).unwrap();
            if st.converged {
                assert!(hits.len() >= 11 || hits.len() == 11, "{}", hits.len());
            }
            assert!(hits.len() <= 11);
            for w in hits.windows(2) {
                assert!(w[0].dist <= w[1].dist);
            }
        }
    }

    #[test]
    fn refined_mode_matches_brute_when_circle_large_enough() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(20_000, 57)));
        let e = ActiveEngine::new(
            ds.clone(),
            2000,
            ActiveParams { mode: SearchMode::Refined, tolerance: 2, ..Default::default() },
        )
        .unwrap();
        let brute = BruteEngine::new(ds);
        let mut agree = 0;
        let queries = generate_queries(20, 2, 58);
        for q in &queries {
            let a = e.knn(q, 11).unwrap();
            let t = brute.knn(q, 11).unwrap();
            let ta: Vec<u32> = t.iter().map(|n| n.id).collect();
            let overlap = a.iter().filter(|n| ta.contains(&n.id)).count();
            if overlap >= 9 {
                agree += 1;
            }
        }
        assert!(agree >= 15, "only {agree}/20 queries had >=9/11 overlap");
    }

    #[test]
    fn classify_close_to_ground_truth() {
        // the paper's experiment: uniform 3-class data, agreement with
        // exact kNN "up to 98%" — require a decent floor at small scale
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(30_000, 59)));
        let e = ActiveEngine::new(ds.clone(), 3000, ActiveParams::default()).unwrap();
        let brute = BruteEngine::new(ds);
        let queries = generate_queries(50, 2, 60);
        let mut agree = 0;
        for q in &queries {
            if e.classify(q, 11).unwrap() == brute.classify(q, 11).unwrap() {
                agree += 1;
            }
        }
        assert!(agree >= 35, "agreement {agree}/50");
    }

    #[test]
    fn trace_records_radius_path() {
        let e = engine(5000, 500, ActiveParams::default());
        let c = e.search(&[0.5, 0.5], 11).unwrap();
        assert!(!c.trace.steps.is_empty());
        assert_eq!(c.trace.steps.last().unwrap().r, c.r);
        assert!(c.trace.converged);
    }

    #[test]
    fn knn_trace_reports_stage_spans_and_feeds_recorder() {
        use crate::obs::{Recorder, Stage};
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(5000, 66)));
        let mut e = ActiveEngine::new(ds, 500, ActiveParams::default()).unwrap();
        let rec = Arc::new(Recorder::new());
        e.set_recorder(Arc::clone(&rec));
        assert!(e.info().supports_trace && e.info().supports_batch);

        let (hits, trace) = e.knn_trace(&[0.5, 0.5], 7).unwrap();
        assert!(!hits.is_empty());
        assert!(!trace.steps.is_empty());
        for stage in [Stage::Coarse, Stage::Scan, Stage::Refine] {
            assert!(trace.spans.iter().any(|s| s.stage == stage), "missing {stage:?} span");
        }
        let snap = rec.snapshot();
        assert_eq!(snap.stage(Stage::Coarse).unwrap().count, 1);
        assert_eq!(snap.stage(Stage::Scan).unwrap().count, 1);
        assert_eq!(snap.stage(Stage::Refine).unwrap().count, 1);

        // recorder-attached engines also time ordinary knn_stats calls
        e.knn_stats(&[0.4, 0.4], 7).unwrap();
        assert_eq!(rec.snapshot().stage(Stage::Coarse).unwrap().count, 2);
    }

    #[test]
    fn l1_metric_works() {
        let e = engine(10_000, 1000, ActiveParams { metric: Metric::L1, ..Default::default() });
        let hits = e.knn(&[0.4, 0.6], 11).unwrap();
        assert!(hits.len() <= 11 && !hits.is_empty());
    }

    #[test]
    fn density_r0_converges_faster_on_sparse_data() {
        // the paper observed fixed r0=100 wastes iterations when data is
        // sparse; the density policy should start closer to the answer
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 61)));
        let fixed = ActiveEngine::new(ds.clone(), 3000, ActiveParams::default()).unwrap();
        let dens = ActiveEngine::new(
            ds,
            3000,
            ActiveParams { r0_policy: R0Policy::Density, ..Default::default() },
        )
        .unwrap();
        let queries = generate_queries(10, 2, 62);
        let (mut itf, mut itd) = (0u32, 0u32);
        for q in &queries {
            itf += fixed.search(q, 11).unwrap().trace.iterations() as u32;
            itd += dens.search(q, 11).unwrap().trace.iterations() as u32;
        }
        assert!(itd <= itf, "density {itd} vs fixed {itf}");
    }

    #[test]
    fn knn_batch_matches_sequential_knn() {
        for params in [
            ActiveParams::default(),
            ActiveParams { mode: SearchMode::Refined, tolerance: 2, ..Default::default() },
        ] {
            let e = engine(10_000, 1000, params);
            let queries = generate_queries(13, 2, 64); // odd batch size
            let views: Vec<&[f64]> = queries.iter().map(|q| q.as_slice()).collect();
            let batched = e.knn_batch(&views, 7);
            assert_eq!(batched.len(), queries.len());
            for (q, b) in queries.iter().zip(batched) {
                let single = e.knn(q, 7).unwrap();
                let b = b.unwrap();
                assert_eq!(b.len(), single.len());
                for (x, y) in b.iter().zip(&single) {
                    assert_eq!(x.id, y.id);
                    assert_eq!(x.dist, y.dist);
                }
            }
        }
    }

    #[test]
    fn knn_batch_isolates_per_query_errors() {
        let e = engine(1000, 300, ActiveParams::default());
        let good = [0.5, 0.5];
        let bad = [0.5]; // wrong dim
        let views: Vec<&[f64]> = vec![&good, &bad, &good];
        let out = e.knn_batch(&views, 5);
        assert!(out[0].is_ok());
        assert!(out[1].is_err());
        assert!(out[2].is_ok());
    }

    #[test]
    fn coarse_skip_reduces_scans_and_keeps_answers_valid() {
        // sparse data + tiny r0: the fixed engine burns exact scans
        // growing the radius; the skipping engine resolves that growth
        // from pyramid bounds and must reach a valid answer
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 61)));
        let plain = ActiveEngine::new(ds.clone(), 3000, ActiveParams::default()).unwrap();
        let skip = ActiveEngine::new(
            ds,
            3000,
            ActiveParams { coarse_skip: true, ..Default::default() },
        )
        .unwrap();
        let queries = generate_queries(10, 2, 65);
        let (mut it_plain, mut it_skip, mut skips) = (0usize, 0usize, 0u32);
        for q in &queries {
            let a = plain.search(q, 11).unwrap();
            let b = skip.search(q, 11).unwrap();
            it_plain += a.trace.iterations();
            it_skip += b.trace.iterations();
            skips += b.trace.coarse_skips;
            assert_eq!(a.trace.coarse_skips, 0);
            if b.trace.converged {
                assert!(b.n_inside >= 11);
            }
            // skipped radii were proven short of k by a sound upper
            // bound, so the final circle is just as valid
            let hits = skip.knn(q, 11).unwrap();
            assert!(hits.len() <= 11);
            for w in hits.windows(2) {
                assert!(w[0].dist <= w[1].dist);
            }
        }
        assert!(skips > 0, "fast-forward never engaged on sparse data");
        assert!(it_skip <= it_plain, "skip {it_skip} vs plain {it_plain} exact scans");
    }

    #[test]
    fn refined_without_dataset_errors() {
        let ds = generate(&SyntheticSpec::paper_default(1000, 63));
        let grid = MultiGrid::build(&ds, 500).unwrap();
        let e = ActiveEngine::from_grid(
            grid,
            ActiveParams { mode: SearchMode::Refined, ..Default::default() },
        );
        assert!(e.knn(&[0.5, 0.5], 5).is_err());
    }

    #[test]
    fn validates_inputs() {
        let e = engine(100, 100, ActiveParams::default());
        assert!(e.knn(&[0.5], 5).is_err());
        assert!(e.knn(&[0.5, 0.5], 0).is_err());
        assert!(e.knn(&[0.5, 0.5], 101).is_err());
    }

    #[test]
    fn query_outside_bounds_still_answers() {
        let e = engine(5000, 500, ActiveParams::default());
        let hits = e.knn(&[3.0, -2.0], 5).unwrap(); // clamps to border
        assert!(!hits.is_empty());
    }
}
