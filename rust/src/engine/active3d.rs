//! 3-D active search — the paper's higher-dimension sketch as a
//! working engine over [`VolumeGrid`], with the d = 3 generalization
//! of Eq. 1 (`r ← round(r·(k/n)^(1/3))`, since n ∝ ball volume ∝ r³).

use std::sync::Arc;

use super::{majority_vote, Neighbor, NnEngine, QueryStats};
use crate::active::radius::{RadiusPolicy, Step};
use crate::active::{SearchStep, SearchTrace};
use crate::data::Dataset;
use crate::error::{AsnnError, Result};
use crate::grid::volume::VolumeGrid;

/// Tuning for the 3-D engine.
#[derive(Debug, Clone)]
pub struct Active3dParams {
    pub r0: u32,
    pub max_iters: u32,
    pub tolerance: u32,
}

impl Default for Active3dParams {
    fn default() -> Self {
        Self { r0: 8, max_iters: 64, tolerance: 0 }
    }
}

/// Active search over a voxel volume.
pub struct Active3dEngine {
    volume: VolumeGrid,
    data: Arc<Dataset>,
    params: Active3dParams,
}

impl Active3dEngine {
    pub fn new(data: Arc<Dataset>, resolution: usize, params: Active3dParams) -> Result<Self> {
        let volume = VolumeGrid::build(&data, resolution)?;
        Ok(Self { volume, data, params })
    }

    pub fn volume(&self) -> &VolumeGrid {
        &self.volume
    }

    /// The radius loop, d = 3 flavor.
    pub fn search(&self, q: &[f64], k: usize) -> Result<(u32, u32, u32, u32, SearchTrace)> {
        if q.len() != 3 {
            return Err(AsnnError::Query(format!(
                "3-D engine requires 3-D queries (got dim {})",
                q.len()
            )));
        }
        if k == 0 || k > self.volume.n_points() {
            return Err(AsnnError::Query(format!(
                "k = {k} out of range for {} points",
                self.volume.n_points()
            )));
        }
        let (cx, cy, cz) = self.volume.voxel_of(q);
        let r_max =
            (self.volume.resolution() as f64 * 3f64.sqrt()).ceil() as u32;
        let mut policy = RadiusPolicy::with_exponent(
            k,
            self.params.tolerance,
            self.params.max_iters,
            r_max,
            3.0,
        );
        let mut r = self.params.r0.max(1);
        let mut trace = SearchTrace::default();
        loop {
            let n = self.volume.count_in_ball(cx, cy, cz, r);
            trace.steps.push(SearchStep { r, n });
            match policy.step(r, n) {
                Step::Done => {
                    trace.converged = true;
                    return Ok((cx, cy, cz, r, trace));
                }
                Step::Settle(rs) => {
                    trace.converged = true;
                    if rs != r {
                        let n2 = self.volume.count_in_ball(cx, cy, cz, rs);
                        trace.steps.push(SearchStep { r: rs, n: n2 });
                    }
                    return Ok((cx, cy, cz, rs, trace));
                }
                Step::Continue(next) => r = next,
                Step::Exhausted => {
                    trace.converged = false;
                    return Ok((cx, cy, cz, r, trace));
                }
            }
        }
    }
}

impl NnEngine for Active3dEngine {
    fn name(&self) -> &'static str {
        "active-3d"
    }

    fn len(&self) -> usize {
        self.volume.n_points()
    }

    fn knn(&self, q: &[f64], k: usize) -> Result<Vec<Neighbor>> {
        Ok(self.knn_stats(q, k)?.0)
    }

    fn knn_stats(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, QueryStats)> {
        let (cx, cy, cz, r, trace) = self.search(q, k)?;
        let cands = self.volume.collect_in_ball(cx, cy, cz, r);
        // refine by true distance (the volume keeps labels, the dataset
        // gives exact coordinates)
        let mut out: Vec<Neighbor> = cands
            .into_iter()
            .map(|(pid, label)| Neighbor {
                id: pid,
                dist: self.data.dist2(pid as usize, q).sqrt(),
                label,
            })
            .collect();
        out.sort_by(|a, b| {
            a.dist
                .partial_cmp(&b.dist)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        out.truncate(k);
        let stats = QueryStats {
            work: trace.steps.iter().map(|s| (s.r as u64).pow(2) * 4).sum(),
            iterations: trace.iterations() as u32,
            converged: trace.converged,
        };
        Ok((out, stats))
    }

    fn classify(&self, q: &[f64], k: usize) -> Result<u16> {
        let (cx, cy, cz, r, _) = self.search(q, k)?;
        let cands = self.volume.collect_in_ball(cx, cy, cz, r);
        Ok(majority_vote(cands.into_iter().map(|(_, l)| l)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, generate_queries, SyntheticSpec};
    use crate::engine::brute::BruteEngine;

    fn engine(n: usize, res: usize, seed: u64) -> (Active3dEngine, BruteEngine) {
        let mut spec = SyntheticSpec::paper_default(n, seed);
        spec.dim = 3;
        let ds = Arc::new(generate(&spec));
        (
            Active3dEngine::new(ds.clone(), res, Active3dParams::default()).unwrap(),
            BruteEngine::new(ds),
        )
    }

    #[test]
    fn returns_k_sorted_neighbors() {
        let (e, _) = engine(20_000, 64, 41);
        for q in generate_queries(5, 3, 42) {
            let hits = e.knn(&q, 11).unwrap();
            assert!(hits.len() <= 11);
            for w in hits.windows(2) {
                assert!(w[0].dist <= w[1].dist);
            }
        }
    }

    #[test]
    fn decent_recall_vs_brute_in_3d() {
        let (e, brute) = engine(30_000, 128, 43);
        let queries = generate_queries(15, 3, 44);
        let mut recall = 0.0;
        for q in &queries {
            let a = e.knn(q, 11).unwrap();
            let t = brute.knn(q, 11).unwrap();
            let ids: Vec<u32> = t.iter().map(|n| n.id).collect();
            recall += a.iter().filter(|h| ids.contains(&h.id)).count() as f64 / 11.0;
        }
        let avg = recall / queries.len() as f64;
        assert!(avg > 0.6, "3-D recall {avg}");
    }

    #[test]
    fn classify_runs_and_is_bounded() {
        let (e, _) = engine(5000, 48, 45);
        let l = e.classify(&[0.5, 0.5, 0.5], 11).unwrap();
        assert!(l < 3);
    }

    #[test]
    fn validates_dim() {
        let (e, _) = engine(1000, 32, 46);
        assert!(e.knn(&[0.5, 0.5], 5).is_err());
        assert!(e.knn(&[0.5, 0.5, 0.5], 0).is_err());
    }

    #[test]
    fn cubic_eq1_converges_faster_than_quadratic_in_3d() {
        // with n ∝ r³, the d=2 update overshoots; the d=3 policy should
        // converge in fewer iterations on average
        let (e, _) = engine(30_000, 96, 47);
        let queries = generate_queries(10, 3, 48);
        let mut iters = 0u64;
        for q in &queries {
            let (_, _, _, _, trace) = e.search(q, 11).unwrap();
            iters += trace.iterations() as u64;
        }
        assert!(iters as f64 / queries.len() as f64 <= 12.0, "iters {iters}");
    }
}
