//! Exact brute-force kNN — the paper's "original kNN" ground truth.
//!
//! Linear scan with a bounded top-k heap. O(N·d) per query: the blue
//! crosses in Fig. 3 that grow linearly with N.

use std::cell::RefCell;
use std::sync::Arc;

use super::{EngineInfo, Neighbor, NnEngine, QueryStats, TopK};
use crate::data::Dataset;
use crate::error::{AsnnError, Result};

thread_local! {
    // one reusable heap per worker thread: the batched path pays for a
    // heap allocation once per thread, not once per query
    static BRUTE_TOP: RefCell<TopK> = const { RefCell::new(TopK::empty()) };
}

/// Exact linear-scan engine.
pub struct BruteEngine {
    data: Arc<Dataset>,
}

impl BruteEngine {
    pub fn new(data: Arc<Dataset>) -> Self {
        Self { data }
    }

    pub fn dataset(&self) -> &Arc<Dataset> {
        &self.data
    }

    /// Exact scan into a caller-owned heap — shared by the single and
    /// batched paths. Stays `f64` end to end: brute force is the
    /// oracle the approximate engines are tested against, so it never
    /// trades precision for speed.
    fn knn_into(&self, q: &[f64], k: usize, top: &mut TopK) -> Result<Vec<Neighbor>> {
        self.check(q, k)?;
        top.reset(k);
        let n = self.data.len();
        for i in 0..n {
            let d2 = self.data.dist2(i, q);
            if d2 < top.worst() {
                top.push(Neighbor { id: i as u32, dist: d2, label: self.data.label(i) });
            }
        }
        let mut hits = top.drain_sorted();
        for h in &mut hits {
            h.dist = h.dist.sqrt(); // convert squared → true distance once
        }
        Ok(hits)
    }

    fn check(&self, q: &[f64], k: usize) -> Result<()> {
        if q.len() != self.data.dim {
            return Err(AsnnError::Query(format!(
                "query dim {} != dataset dim {}",
                q.len(),
                self.data.dim
            )));
        }
        if k == 0 || k > self.data.len() {
            return Err(AsnnError::Query(format!(
                "k = {k} out of range for {} points",
                self.data.len()
            )));
        }
        Ok(())
    }
}

impl NnEngine for BruteEngine {
    fn name(&self) -> &'static str {
        "brute"
    }

    fn info(&self) -> EngineInfo {
        EngineInfo { name: self.name(), supports_batch: true, supports_trace: false }
    }

    fn len(&self) -> usize {
        self.data.len()
    }

    fn knn(&self, q: &[f64], k: usize) -> Result<Vec<Neighbor>> {
        Ok(self.knn_stats(q, k)?.0)
    }

    fn knn_stats(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, QueryStats)> {
        let hits = BRUTE_TOP.with(|t| self.knn_into(q, k, &mut t.borrow_mut()))?;
        Ok((hits, QueryStats { work: self.data.len() as u64, iterations: 0, converged: true }))
    }

    /// Batched exact scan: one thread-local heap borrow for the whole
    /// batch.
    fn knn_batch(&self, queries: &[&[f64]], k: usize) -> Vec<Result<Vec<Neighbor>>> {
        BRUTE_TOP.with(|t| {
            let top = &mut *t.borrow_mut();
            queries.iter().map(|q| self.knn_into(q, k, top)).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, generate_queries, SyntheticSpec};

    fn engine(n: usize, seed: u64) -> BruteEngine {
        BruteEngine::new(Arc::new(generate(&SyntheticSpec::paper_default(n, seed))))
    }

    #[test]
    fn finds_self_at_distance_zero() {
        let e = engine(100, 1);
        let q = e.dataset().point(42).to_vec();
        let hits = e.knn(&q, 1).unwrap();
        assert_eq!(hits[0].id, 42);
        assert!(hits[0].dist < 1e-12);
    }

    #[test]
    fn results_sorted_ascending() {
        let e = engine(500, 2);
        for q in generate_queries(5, 2, 3) {
            let hits = e.knn(&q, 11).unwrap();
            assert_eq!(hits.len(), 11);
            for w in hits.windows(2) {
                assert!(w[0].dist <= w[1].dist);
            }
        }
    }

    #[test]
    fn matches_exhaustive_sort() {
        let e = engine(200, 4);
        let q = [0.3, 0.7];
        let hits = e.knn(&q, 7).unwrap();
        let mut all: Vec<(f64, u32)> = (0..200)
            .map(|i| (e.dataset().dist2(i, &q).sqrt(), i as u32))
            .collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (h, (d, id)) in hits.iter().zip(all.iter()) {
            assert!((h.dist - d).abs() < 1e-12);
            assert_eq!(h.id, *id);
        }
    }

    #[test]
    fn knn_batch_matches_sequential_exactly() {
        let e = engine(400, 8);
        let queries = generate_queries(9, 2, 9);
        let views: Vec<&[f64]> = queries.iter().map(|q| q.as_slice()).collect();
        let batched = e.knn_batch(&views, 5);
        for (q, b) in queries.iter().zip(batched) {
            let single = e.knn(q, 5).unwrap();
            assert_eq!(b.unwrap(), single); // bitwise-identical f64 path
        }
    }

    #[test]
    fn validates_inputs() {
        let e = engine(10, 5);
        assert!(e.knn(&[0.5], 3).is_err()); // wrong dim
        assert!(e.knn(&[0.5, 0.5], 0).is_err()); // k = 0
        assert!(e.knn(&[0.5, 0.5], 11).is_err()); // k > n
    }

    #[test]
    fn stats_report_full_scan() {
        let e = engine(321, 6);
        let (_, st) = e.knn_stats(&[0.1, 0.9], 3).unwrap();
        assert_eq!(st.work, 321);
        assert!(st.converged);
    }

    #[test]
    fn classify_majority_of_labels() {
        let e = engine(300, 7);
        let label = e.classify(&[0.5, 0.5], 11).unwrap();
        assert!(label < 3);
    }
}
