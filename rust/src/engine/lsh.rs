//! Locality-sensitive hashing engine (Indyk–Motwani — paper ref. [7]).
//!
//! p-stable (Gaussian projection) LSH: `L` tables, each hashing a point
//! by `M` concatenated quantized random projections. Queries probe the
//! query's bucket in every table (plus neighboring buckets via offset
//! probing), then rank the candidate union exactly. Approximate — the
//! recall/latency trade-off is exercised in the EXT-ENGINES bench.

use std::collections::HashMap;
use std::sync::Arc;

use super::{Neighbor, NnEngine, QueryStats, TopK};
use crate::data::Dataset;
use crate::error::{AsnnError, Result};
use crate::util::rng::Rng;

/// LSH tuning parameters.
#[derive(Debug, Clone)]
pub struct LshParams {
    /// Number of hash tables.
    pub tables: usize,
    /// Projections concatenated per table key.
    pub projections: usize,
    /// Quantization bucket width in data units.
    pub bucket_width: f64,
    /// Probe the ±1 offset of each projection (multiprobe) — trades
    /// query time for recall.
    pub multiprobe: bool,
    pub seed: u64,
}

impl Default for LshParams {
    fn default() -> Self {
        Self { tables: 8, projections: 4, bucket_width: 0.05, multiprobe: true, seed: 0xA11CE }
    }
}

struct Table {
    /// Projection vectors, row-major `[projections × dim]`.
    projections: Vec<f64>,
    offsets: Vec<f64>,
    buckets: HashMap<u64, Vec<u32>>,
}

/// Approximate LSH engine.
pub struct LshEngine {
    data: Arc<Dataset>,
    params: LshParams,
    tables: Vec<Table>,
}

impl LshEngine {
    pub fn build(data: Arc<Dataset>, params: LshParams) -> Self {
        let mut rng = Rng::new(params.seed);
        let dim = data.dim;
        let mut tables = Vec::with_capacity(params.tables);
        for _ in 0..params.tables {
            let mut projections = Vec::with_capacity(params.projections * dim);
            let mut offsets = Vec::with_capacity(params.projections);
            for _ in 0..params.projections {
                for _ in 0..dim {
                    projections.push(rng.normal());
                }
                offsets.push(rng.uniform(0.0, params.bucket_width));
            }
            let mut table = Table { projections, offsets, buckets: HashMap::new() };
            for i in 0..data.len() {
                let key = Self::key_of(&table, &params, data.point(i));
                table.buckets.entry(key).or_default().push(i as u32);
            }
            tables.push(table);
        }
        Self { data, params, tables }
    }

    /// Quantized projections of `p`, for one table.
    fn raw_hashes(table: &Table, params: &LshParams, p: &[f64]) -> Vec<i64> {
        let dim = p.len();
        (0..params.projections)
            .map(|j| {
                let proj = &table.projections[j * dim..(j + 1) * dim];
                let dot: f64 = proj.iter().zip(p).map(|(a, b)| a * b).sum();
                ((dot + table.offsets[j]) / params.bucket_width).floor() as i64
            })
            .collect()
    }

    /// Combine quantized projections into a single bucket key (FNV-1a).
    fn combine(hashes: &[i64]) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for &v in hashes {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
        h
    }

    fn key_of(table: &Table, params: &LshParams, p: &[f64]) -> u64 {
        Self::combine(&Self::raw_hashes(table, params, p))
    }

    pub fn dataset(&self) -> &Arc<Dataset> {
        &self.data
    }

    fn check(&self, q: &[f64], k: usize) -> Result<()> {
        if q.len() != self.data.dim {
            return Err(AsnnError::Query(format!(
                "query dim {} != dataset dim {}",
                q.len(),
                self.data.dim
            )));
        }
        if k == 0 || k > self.data.len() {
            return Err(AsnnError::Query(format!(
                "k = {k} out of range for {} points",
                self.data.len()
            )));
        }
        Ok(())
    }
}

impl NnEngine for LshEngine {
    fn name(&self) -> &'static str {
        "lsh"
    }

    fn len(&self) -> usize {
        self.data.len()
    }

    fn knn(&self, q: &[f64], k: usize) -> Result<Vec<Neighbor>> {
        Ok(self.knn_stats(q, k)?.0)
    }

    fn knn_stats(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, QueryStats)> {
        self.check(q, k)?;
        let mut seen: Vec<bool> = vec![false; self.data.len()];
        let mut top = TopK::new(k);
        let mut work = 0u64;
        for table in &self.tables {
            let hashes = Self::raw_hashes(table, &self.params, q);
            let mut keys = vec![Self::combine(&hashes)];
            if self.params.multiprobe {
                // probe ±1 on each projection (2·M extra buckets/table)
                for j in 0..hashes.len() {
                    for delta in [-1i64, 1] {
                        let mut h = hashes.clone();
                        h[j] += delta;
                        keys.push(Self::combine(&h));
                    }
                }
            }
            for key in keys {
                if let Some(bucket) = table.buckets.get(&key) {
                    for &pid in bucket {
                        if !seen[pid as usize] {
                            seen[pid as usize] = true;
                            work += 1;
                            let d2 = self.data.dist2(pid as usize, q);
                            if d2 < top.worst() {
                                top.push(Neighbor {
                                    id: pid,
                                    dist: d2,
                                    label: self.data.label(pid as usize),
                                });
                            }
                        }
                    }
                }
            }
        }
        let mut hits = top.into_sorted();
        for h in &mut hits {
            h.dist = h.dist.sqrt();
        }
        Ok((hits, QueryStats { work, iterations: 0, converged: true }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, generate_queries, SyntheticSpec};
    use crate::engine::brute::BruteEngine;

    fn engines(n: usize, seed: u64) -> (LshEngine, BruteEngine) {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(n, seed)));
        (LshEngine::build(ds.clone(), LshParams::default()), BruteEngine::new(ds))
    }

    fn recall(a: &[Neighbor], truth: &[Neighbor]) -> f64 {
        let truth_ids: Vec<u32> = truth.iter().map(|n| n.id).collect();
        let hit = a.iter().filter(|n| truth_ids.contains(&n.id)).count();
        hit as f64 / truth.len() as f64
    }

    #[test]
    fn recall_is_high_on_uniform_2d() {
        let (lsh, brute) = engines(5000, 31);
        let mut total = 0.0;
        let queries = generate_queries(20, 2, 32);
        for q in &queries {
            let a = lsh.knn(q, 11).unwrap();
            let t = brute.knn(q, 11).unwrap();
            total += recall(&a, &t);
        }
        let avg = total / queries.len() as f64;
        assert!(avg > 0.6, "avg recall {avg}");
    }

    #[test]
    fn probes_fraction_of_dataset() {
        let (lsh, _) = engines(20_000, 33);
        let (_, st) = lsh.knn_stats(&[0.5, 0.5], 11).unwrap();
        assert!(st.work < 10_000, "probed {}", st.work);
        assert!(st.work > 0);
    }

    #[test]
    fn finds_exact_duplicate() {
        let (lsh, _) = engines(2000, 34);
        let q = lsh.dataset().point(100).to_vec();
        let hits = lsh.knn(&q, 5).unwrap();
        assert!(hits.iter().any(|h| h.id == 100 && h.dist < 1e-12));
    }

    #[test]
    fn multiprobe_increases_candidates() {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(5000, 35)));
        let base = LshEngine::build(
            ds.clone(),
            LshParams { multiprobe: false, ..Default::default() },
        );
        let probed = LshEngine::build(ds, LshParams { multiprobe: true, ..Default::default() });
        let (_, s0) = base.knn_stats(&[0.4, 0.4], 11).unwrap();
        let (_, s1) = probed.knn_stats(&[0.4, 0.4], 11).unwrap();
        assert!(s1.work >= s0.work);
    }

    #[test]
    fn validates_inputs() {
        let (lsh, _) = engines(100, 36);
        assert!(lsh.knn(&[0.5], 3).is_err());
        assert!(lsh.knn(&[0.5, 0.5], 0).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, _) = engines(1000, 37);
        let (b, _) = engines(1000, 37);
        let ha = a.knn(&[0.3, 0.3], 7).unwrap();
        let hb = b.knn(&[0.3, 0.3], 7).unwrap();
        assert_eq!(ha, hb);
    }
}
