//! KD-tree engine (Bentley 1975 — the paper's reference [6]).
//!
//! Median-split construction over an index permutation; exact
//! branch-and-bound kNN with a bounded top-k heap. Expected O(log N)
//! per query in low dimension — the "most efficient algorithm could
//! take only log(N)" line in the paper's §1.

use std::sync::Arc;

use super::{Neighbor, NnEngine, QueryStats, TopK};
use crate::data::Dataset;
use crate::error::{AsnnError, Result};

/// Flat-array KD-tree node (indices into `nodes`; u32::MAX = leaf end).
#[derive(Debug, Clone, Copy)]
struct Node {
    /// Point id at this node (split point).
    point: u32,
    /// Split axis.
    axis: u8,
    left: u32,
    right: u32,
}

const NIL: u32 = u32::MAX;

/// Exact KD-tree engine.
pub struct KdTreeEngine {
    data: Arc<Dataset>,
    nodes: Vec<Node>,
    root: u32,
}

impl KdTreeEngine {
    pub fn build(data: Arc<Dataset>) -> Self {
        let n = data.len();
        let mut ids: Vec<u32> = (0..n as u32).collect();
        let mut nodes = Vec::with_capacity(n);
        let root = Self::build_rec(&data, &mut ids[..], 0, &mut nodes);
        Self { data, nodes, root }
    }

    fn build_rec(data: &Dataset, ids: &mut [u32], depth: usize, nodes: &mut Vec<Node>) -> u32 {
        if ids.is_empty() {
            return NIL;
        }
        let axis = depth % data.dim;
        let mid = ids.len() / 2;
        // median partition by the axis coordinate
        ids.select_nth_unstable_by(mid, |&a, &b| {
            data.point(a as usize)[axis]
                .partial_cmp(&data.point(b as usize)[axis])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let point = ids[mid];
        let slot = nodes.len() as u32;
        nodes.push(Node { point, axis: axis as u8, left: NIL, right: NIL });
        let (lo, hi) = ids.split_at_mut(mid);
        let left = Self::build_rec(data, lo, depth + 1, nodes);
        let right = Self::build_rec(data, &mut hi[1..], depth + 1, nodes);
        nodes[slot as usize].left = left;
        nodes[slot as usize].right = right;
        slot
    }

    pub fn dataset(&self) -> &Arc<Dataset> {
        &self.data
    }

    fn search(&self, node: u32, q: &[f64], top: &mut TopK, work: &mut u64) {
        if node == NIL {
            return;
        }
        let nd = self.nodes[node as usize];
        let pid = nd.point as usize;
        *work += 1;
        let d2 = self.data.dist2(pid, q);
        if d2 < top.worst() {
            top.push(Neighbor { id: nd.point, dist: d2, label: self.data.label(pid) });
        }
        let axis = nd.axis as usize;
        let delta = q[axis] - self.data.point(pid)[axis];
        let (near, far) = if delta < 0.0 { (nd.left, nd.right) } else { (nd.right, nd.left) };
        self.search(near, q, top, work);
        // prune the far side if the splitting plane is beyond the worst kept
        if delta * delta < top.worst() {
            self.search(far, q, top, work);
        }
    }

    fn check(&self, q: &[f64], k: usize) -> Result<()> {
        if q.len() != self.data.dim {
            return Err(AsnnError::Query(format!(
                "query dim {} != dataset dim {}",
                q.len(),
                self.data.dim
            )));
        }
        if k == 0 || k > self.data.len() {
            return Err(AsnnError::Query(format!(
                "k = {k} out of range for {} points",
                self.data.len()
            )));
        }
        Ok(())
    }
}

impl NnEngine for KdTreeEngine {
    fn name(&self) -> &'static str {
        "kdtree"
    }

    fn len(&self) -> usize {
        self.data.len()
    }

    fn knn(&self, q: &[f64], k: usize) -> Result<Vec<Neighbor>> {
        Ok(self.knn_stats(q, k)?.0)
    }

    fn knn_stats(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, QueryStats)> {
        self.check(q, k)?;
        let mut top = TopK::new(k);
        let mut work = 0u64;
        self.search(self.root, q, &mut top, &mut work);
        let mut hits = top.into_sorted();
        for h in &mut hits {
            h.dist = h.dist.sqrt();
        }
        Ok((hits, QueryStats { work, iterations: 0, converged: true }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, generate_queries, SyntheticSpec};
    use crate::engine::brute::BruteEngine;

    fn pair(n: usize, seed: u64) -> (KdTreeEngine, BruteEngine) {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(n, seed)));
        (KdTreeEngine::build(ds.clone()), BruteEngine::new(ds))
    }

    #[test]
    fn agrees_with_brute_force() {
        let (kd, brute) = pair(800, 11);
        for q in generate_queries(20, 2, 12) {
            let a = kd.knn(&q, 11).unwrap();
            let b = brute.knn(&q, 11).unwrap();
            for (x, y) in a.iter().zip(b.iter()) {
                assert!((x.dist - y.dist).abs() < 1e-12, "dists differ");
            }
            // id sets match (order can differ only on exact ties)
            let mut ia: Vec<u32> = a.iter().map(|n| n.id).collect();
            let mut ib: Vec<u32> = b.iter().map(|n| n.id).collect();
            ia.sort();
            ib.sort();
            assert_eq!(ia, ib);
        }
    }

    #[test]
    fn prunes_most_of_the_tree() {
        let (kd, _) = pair(20_000, 13);
        let (_, st) = kd.knn_stats(&[0.5, 0.5], 11).unwrap();
        assert!(st.work < 4_000, "visited {} of 20000", st.work);
    }

    #[test]
    fn handles_k_equals_n() {
        let (kd, brute) = pair(50, 14);
        let a = kd.knn(&[0.2, 0.2], 50).unwrap();
        let b = brute.knn(&[0.2, 0.2], 50).unwrap();
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x.dist - y.dist).abs() < 1e-12);
        }
    }

    #[test]
    fn validates_inputs() {
        let (kd, _) = pair(10, 15);
        assert!(kd.knn(&[0.5, 0.5, 0.5], 3).is_err());
        assert!(kd.knn(&[0.5, 0.5], 0).is_err());
        assert!(kd.knn(&[0.5, 0.5], 11).is_err());
    }

    #[test]
    fn single_point_tree() {
        let ds = Arc::new(
            crate::data::Dataset::new(2, vec![0.4, 0.6], vec![0], 1).unwrap(),
        );
        let kd = KdTreeEngine::build(ds);
        let hits = kd.knn(&[0.0, 0.0], 1).unwrap();
        assert_eq!(hits[0].id, 0);
    }

    #[test]
    fn duplicate_points_all_found() {
        let pts = vec![0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.1, 0.1];
        let ds = Arc::new(crate::data::Dataset::new(2, pts, vec![0, 0, 0, 1], 2).unwrap());
        let kd = KdTreeEngine::build(ds);
        let hits = kd.knn(&[0.5, 0.5], 3).unwrap();
        assert!(hits.iter().all(|h| h.dist < 1e-12));
    }
}
