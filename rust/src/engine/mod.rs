//! Nearest-neighbor engines behind a common trait.
//!
//! - [`brute`] — exact linear scan, the paper's "original kNN" ground
//!   truth;
//! - [`kdtree`] — Bentley '75 KD-tree (paper ref. [6]);
//! - [`lsh`] — p-stable locality-sensitive hashing (paper ref. [7]);
//! - [`active`] — the paper's contribution, pure rust;
//! - [`active_pjrt`] — same algorithm with the circle-count/scan hot
//!   spot executed by AOT-compiled XLA artifacts via PJRT;
//! - [`active3d`] — the paper's §3 higher-dimension sketch over a
//!   voxel volume (d = 3 Eq. 1);
//! - [`chaos`] — fault-injection wrapper around any engine (latency,
//!   errors, panics) for resilience testing of the coordinator.

pub mod active;
pub mod active3d;
#[cfg(feature = "pjrt")]
pub mod active_pjrt;
pub mod brute;
pub mod chaos;
pub mod kdtree;
pub mod lsh;

use crate::error::Result;
use crate::obs::trace::{SearchTrace, Stage};
use crate::util::timer::Timer;

/// Static identity + capability card for an engine, reported by
/// [`NnEngine::info`]. The router keys its breaker/fallback bookkeeping
/// on `info().name` and gates feature dispatch on the capability flags
/// instead of matching engine-name strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineInfo {
    /// Stable engine name (also the wire/registration identity).
    pub name: &'static str,
    /// True when `knn_batch` is a native batched implementation that
    /// amortizes scratch across queries (not the sequential default).
    pub supports_batch: bool,
    /// True when `knn_trace` reports real per-stage spans (coarse /
    /// scan / refine) rather than the single whole-query span the
    /// default implementation synthesizes.
    pub supports_trace: bool,
}

/// One returned neighbor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub id: u32,
    /// Engine-native distance: true Euclidean for vector engines,
    /// pixel-space distance for the active engine in `approx` mode,
    /// true Euclidean after refinement in `refined` mode.
    pub dist: f64,
    pub label: u16,
}

/// Summary of one query's work (for benches and the coordinator).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct QueryStats {
    /// Distance evaluations / pixels touched (engine-specific unit).
    pub work: u64,
    /// Active-search iterations (0 for non-active engines).
    pub iterations: u32,
    /// Whether the engine converged exactly (active) / always true.
    pub converged: bool,
}

/// A k-nearest-neighbor engine over a fixed dataset.
pub trait NnEngine: Send + Sync {
    fn name(&self) -> &'static str;

    /// Identity and capability card. The default claims no native
    /// batching and no staged tracing; engines with real
    /// implementations override it.
    fn info(&self) -> EngineInfo {
        EngineInfo { name: self.name(), supports_batch: false, supports_trace: false }
    }

    /// Number of indexed points.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// k nearest neighbors of `q`, sorted by ascending distance.
    fn knn(&self, q: &[f64], k: usize) -> Result<Vec<Neighbor>>;

    /// Batched kNN: one result per query, in input order. The default
    /// walks the batch sequentially; engines override it to amortize
    /// per-thread scratch buffers across the batch so the steady-state
    /// hot path performs no allocations beyond the returned hit vecs.
    /// Per-query failures (bad dim, k out of range) land in their own
    /// slot and never poison the rest of the batch.
    fn knn_batch(&self, queries: &[&[f64]], k: usize) -> Vec<Result<Vec<Neighbor>>> {
        queries.iter().map(|q| self.knn(q, k)).collect()
    }

    /// kNN with work accounting.
    fn knn_stats(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, QueryStats)> {
        let hits = self.knn(q, k)?;
        Ok((hits, QueryStats { converged: true, ..Default::default() }))
    }

    /// kNN with a populated [`SearchTrace`] — the record behind the
    /// `TRACE` wire verb. The default times the whole query as one
    /// `scan` span and carries over the engine's own `knn_stats`
    /// convergence flag; staged engines override it with real
    /// per-stage spans and the radius schedule.
    fn knn_trace(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, SearchTrace)> {
        let t = Timer::new();
        let (hits, stats) = self.knn_stats(q, k)?;
        let mut trace = SearchTrace { converged: stats.converged, ..Default::default() };
        trace.push_span(Stage::Scan, t.elapsed_ns());
        Ok((hits, trace))
    }

    /// Majority-vote classification over the k nearest neighbors.
    /// The active engine overrides this with the paper's per-class
    /// count-image vote.
    fn classify(&self, q: &[f64], k: usize) -> Result<u16> {
        let hits = self.knn(q, k)?;
        Ok(majority_vote(hits.iter().map(|h| h.label)))
    }
}

/// Majority vote with deterministic tie-breaking (lowest label wins —
/// matters for reproducibility across engines).
pub fn majority_vote(labels: impl Iterator<Item = u16>) -> u16 {
    let mut counts: Vec<(u16, u32)> = Vec::new();
    for l in labels {
        match counts.iter_mut().find(|(lbl, _)| *lbl == l) {
            Some((_, c)) => *c += 1,
            None => counts.push((l, 1)),
        }
    }
    counts
        .into_iter()
        .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
        .map(|(l, _)| l)
        .unwrap_or(0)
}

/// Bounded max-heap of the k best (smallest-distance) neighbors —
/// shared by the brute and KD-tree engines.
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    /// Max-heap by distance: `heap[0]` is the current worst of the best.
    heap: Vec<Neighbor>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        Self { k, heap: Vec::with_capacity(k + 1) }
    }

    /// An empty heap with `k = 0`, `const`-constructible so it can sit
    /// in a `thread_local!` scratch slot. Call [`reset`](Self::reset)
    /// with the real `k` before use — until then every push is dropped.
    pub const fn empty() -> Self {
        Self { k: 0, heap: Vec::new() }
    }

    /// Re-arm for a new query of size `k`, keeping the heap allocation.
    pub fn reset(&mut self, k: usize) {
        self.k = k;
        self.heap.clear();
    }

    /// Like [`into_sorted`](Self::into_sorted), but leaves the emptied
    /// heap (and its allocation) behind for reuse by the next query.
    pub fn drain_sorted(&mut self) -> Vec<Neighbor> {
        self.heap.sort_by(|a, b| {
            a.dist
                .partial_cmp(&b.dist)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        let out = self.heap.clone();
        self.heap.clear();
        out
    }

    /// Current worst distance among the kept k (∞ until full, −∞ for
    /// the degenerate `k = 0` so callers prune everything).
    #[inline]
    pub fn worst(&self) -> f64 {
        if self.heap.len() < self.k {
            f64::INFINITY
        } else {
            self.heap.first().map_or(f64::NEG_INFINITY, |top| top.dist)
        }
    }

    #[inline]
    pub fn push(&mut self, n: Neighbor) {
        if self.heap.len() < self.k {
            self.heap.push(n);
            self.sift_up(self.heap.len() - 1);
        } else if self.heap.first().is_some_and(|top| n.dist < top.dist) {
            self.heap[0] = n;
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].dist > self.heap[parent].dist {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < self.heap.len() && self.heap[l].dist > self.heap[largest].dist {
                largest = l;
            }
            if r < self.heap.len() && self.heap[r].dist > self.heap[largest].dist {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }

    /// Extract ascending-by-distance, ties broken by id (determinism).
    pub fn into_sorted(mut self) -> Vec<Neighbor> {
        self.heap.sort_by(|a, b| {
            a.dist
                .partial_cmp(&b.dist)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        self.heap
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nb(id: u32, dist: f64) -> Neighbor {
        Neighbor { id, dist, label: 0 }
    }

    #[test]
    fn topk_keeps_smallest() {
        let mut t = TopK::new(3);
        for (i, d) in [5.0, 1.0, 4.0, 2.0, 3.0, 0.5].iter().enumerate() {
            t.push(nb(i as u32, *d));
        }
        let out = t.into_sorted();
        let dists: Vec<f64> = out.iter().map(|n| n.dist).collect();
        assert_eq!(dists, vec![0.5, 1.0, 2.0]);
    }

    #[test]
    fn topk_worst_tracks_heap() {
        let mut t = TopK::new(2);
        assert_eq!(t.worst(), f64::INFINITY);
        t.push(nb(0, 3.0));
        assert_eq!(t.worst(), f64::INFINITY); // not yet full
        t.push(nb(1, 1.0));
        assert_eq!(t.worst(), 3.0);
        t.push(nb(2, 2.0));
        assert_eq!(t.worst(), 2.0);
    }

    #[test]
    fn topk_underfull_returns_all() {
        let mut t = TopK::new(10);
        t.push(nb(0, 1.0));
        t.push(nb(1, 0.5));
        assert_eq!(t.into_sorted().len(), 2);
    }

    #[test]
    fn topk_reset_reuses_allocation_across_queries() {
        let mut t = TopK::empty();
        assert_eq!(t.worst(), f64::NEG_INFINITY); // unarmed: prune all
        t.push(nb(0, 1.0)); // dropped — not armed yet
        assert!(t.is_empty());
        t.reset(2);
        for (i, d) in [4.0, 1.0, 3.0, 2.0].iter().enumerate() {
            t.push(nb(i as u32, *d));
        }
        let first: Vec<f64> = t.drain_sorted().iter().map(|n| n.dist).collect();
        assert_eq!(first, vec![1.0, 2.0]);
        // second query through the same scratch
        t.reset(1);
        t.push(nb(7, 9.0));
        t.push(nb(8, 0.5));
        let second = t.drain_sorted();
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].id, 8);
        assert!(t.is_empty());
    }

    #[test]
    fn majority_vote_basics() {
        assert_eq!(majority_vote([1, 1, 2].into_iter()), 1);
        assert_eq!(majority_vote([2, 2, 1, 1, 1].into_iter()), 1);
        assert_eq!(majority_vote(std::iter::empty()), 0);
    }

    #[test]
    fn majority_vote_tie_breaks_low() {
        assert_eq!(majority_vote([2, 1].into_iter()), 1);
        assert_eq!(majority_vote([3, 3, 0, 0].into_iter()), 0);
    }
}
