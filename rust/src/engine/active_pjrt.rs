//! Active search with the pixel-scan hot spot executed by AOT-compiled
//! XLA artifacts (L1 Pallas `disk_count` / `neighbor_scan` kernels,
//! lowered through the L2 jax model, run via PJRT).
//!
//! The control loop (Eq. 1, bracketing, termination) stays in rust; the
//! per-iteration circle count and the final candidate extraction run as
//! compiled executables on the runtime service thread. Window sizes are
//! static per artifact, so the engine picks the smallest compiled "zoom
//! level" that contains the current circle and falls back to the native
//! scan when the circle outgrows the ladder.

use std::sync::Arc;

use super::active::{ActiveEngine, ActiveParams, FinalCircle};
use super::{Neighbor, NnEngine, QueryStats};
use crate::active::scan;
use crate::active::window::WindowLadder;
use crate::config::{Metric, SearchMode};
use crate::data::Dataset;
use crate::error::{AsnnError, Result};
use crate::runtime::RuntimeService;

/// PJRT-accelerated active-search engine.
pub struct ActivePjrtEngine {
    inner: ActiveEngine,
    service: RuntimeService,
    ladder: WindowLadder,
}

impl ActivePjrtEngine {
    /// Build over a dataset; the runtime service must expose
    /// `disk_count_w*_b1` artifacts whose class count matches.
    pub fn new(
        data: Arc<Dataset>,
        resolution: usize,
        params: ActiveParams,
        service: RuntimeService,
    ) -> Result<Self> {
        let windows = service.disk_count_windows();
        if windows.is_empty() {
            return Err(AsnnError::Runtime(
                "no batch-1 disk_count artifacts (run `make artifacts`)".into(),
            ));
        }
        for &w in &windows {
            let name = format!("disk_count_w{w}_b1");
            let meta = service
                .meta(&name)
                .ok_or_else(|| AsnnError::Runtime(format!("missing artifact {name}")))?;
            if meta.classes != data.num_classes {
                return Err(AsnnError::Runtime(format!(
                    "artifact {} compiled for {} classes, dataset has {}",
                    meta.name, meta.classes, data.num_classes
                )));
            }
        }
        let inner = ActiveEngine::new(data, resolution, params)?;
        let ladder = WindowLadder::new(windows);
        Ok(Self { inner, service, ladder })
    }

    pub fn ladder(&self) -> &WindowLadder {
        &self.ladder
    }

    pub fn inner(&self) -> &ActiveEngine {
        &self.inner
    }

    pub fn service(&self) -> &RuntimeService {
        &self.service
    }

    /// Count points in the circle through the best-fitting artifact;
    /// native scan when the circle outgrows the ladder. Returns
    /// (total, per-class counts).
    fn count_via_pjrt(&self, cx: u32, cy: u32, r: u32, k: usize) -> Result<(u64, Vec<f32>)> {
        let grid = self.inner.grid();
        let metric = self.inner.params().metric;
        if let Some(w) = self.ladder.select(r) {
            let name = format!("disk_count_w{w}_b1");
            let c = grid.num_classes();
            let mut window = vec![0f32; c * w * w];
            grid.crop_classes_f32(cx, cy, w, &mut window);
            let out =
                self.service
                    .disk_count(&name, window, r as f32, k as f32, metric == Metric::L1)?;
            return Ok((out.total as u64, out.class_counts));
        }
        // fallback: native row-span scan (radius beyond the ladder)
        let mut cls = vec![0u64; grid.num_classes()];
        scan::class_counts_in_disk(grid, cx, cy, r, metric, &mut cls);
        let total: u64 = cls.iter().sum();
        Ok((total, cls.iter().map(|&v| v as f32).collect()))
    }

    /// Run the search loop with PJRT-backed counting.
    pub fn search(&self, q: &[f64], k: usize) -> Result<FinalCircle> {
        let mut err: Option<AsnnError> = None;
        let circle = self.inner.search_with(q, k, |cx, cy, r| {
            match self.count_via_pjrt(cx, cy, r, k) {
                Ok((n, _)) => n,
                Err(e) => {
                    err = Some(e);
                    0
                }
            }
        })?;
        if let Some(e) = err {
            return Err(e);
        }
        Ok(circle)
    }

    /// Batched search: run many queries' radius loops in lockstep,
    /// grouping same-window queries into the `disk_count_w*_b16`
    /// artifacts each round. All queries start at the same r₀, so the
    /// first rounds batch perfectly; stragglers finish in smaller
    /// groups or singly. Returns one final circle per query.
    pub fn batch_search(&self, queries: &[Vec<f64>], k: usize) -> Result<Vec<FinalCircle>> {
        use crate::active::radius::{RadiusPolicy, Step};
        use crate::active::{SearchStep, SearchTrace};

        let grid = self.inner.grid();
        let geom = grid.geometry();
        let params = self.inner.params();
        let metric_l1 = params.metric == Metric::L1;
        let r_max = (grid.resolution() as f64 * std::f64::consts::SQRT_2).ceil() as u32;

        struct QState {
            cx: u32,
            cy: u32,
            r: u32,
            policy: RadiusPolicy,
            trace: SearchTrace,
            done: Option<FinalCircle>,
            recount: bool,
        }
        let mut states: Vec<QState> = Vec::with_capacity(queries.len());
        for q in queries {
            if q.len() != 2 {
                return Err(AsnnError::Query("batch_search requires 2-D queries".into()));
            }
            let (cx, cy) = geom.pixel_of(q[0], q[1]);
            states.push(QState {
                cx,
                cy,
                r: params.r0.max(1),
                policy: RadiusPolicy::new(k, params.tolerance, params.max_iters, r_max),
                trace: SearchTrace::default(),
                done: None,
                recount: false,
            });
        }

        loop {
            // group live queries by their selected window size
            let mut groups: std::collections::BTreeMap<usize, Vec<usize>> =
                std::collections::BTreeMap::new();
            let mut native: Vec<usize> = Vec::new();
            for (i, s) in states.iter().enumerate() {
                if s.done.is_some() {
                    continue;
                }
                match self.ladder.select(s.r) {
                    Some(w) => groups.entry(w).or_default().push(i),
                    None => native.push(i),
                }
            }
            if groups.is_empty() && native.is_empty() {
                break;
            }

            // counts for this round
            let mut counts: Vec<(usize, u64)> = Vec::new();
            for (w, idxs) in &groups {
                let w = *w;
                let b16 = format!("disk_count_w{w}_b16");
                let use_batch = idxs.len() >= 2 && self.service.meta(&b16).is_some();
                if use_batch {
                    for chunk in idxs.chunks(16) {
                        let mut windows = vec![0f32; 16 * grid.num_classes() * w * w];
                        let mut rs = vec![1f32; 16];
                        for (slot, &qi) in chunk.iter().enumerate() {
                            let s = &states[qi];
                            grid.crop_classes_f32(
                                s.cx,
                                s.cy,
                                w,
                                &mut windows[slot * grid.num_classes() * w * w
                                    ..(slot + 1) * grid.num_classes() * w * w],
                            );
                            rs[slot] = s.r as f32;
                        }
                        let outs = self.service.disk_count_batch(
                            &b16,
                            windows,
                            rs,
                            k as f32,
                            metric_l1,
                        )?;
                        for (slot, &qi) in chunk.iter().enumerate() {
                            counts.push((qi, outs[slot].total as u64));
                        }
                    }
                } else {
                    for &qi in idxs {
                        let s = &states[qi];
                        let (n, _) = self.count_via_pjrt(s.cx, s.cy, s.r, k)?;
                        counts.push((qi, n));
                    }
                }
            }
            for &qi in &native {
                let s = &states[qi];
                let (n, _) = self.count_via_pjrt(s.cx, s.cy, s.r, k)?;
                counts.push((qi, n));
            }

            // advance every live query one policy step
            for (qi, n) in counts {
                let s = &mut states[qi];
                if s.recount {
                    // this round's count was the settle-radius recount
                    s.trace.steps.push(SearchStep { r: s.r, n });
                    s.trace.converged = true;
                    s.done = Some(FinalCircle {
                        cx: s.cx,
                        cy: s.cy,
                        r: s.r,
                        n_inside: n,
                        trace: std::mem::take(&mut s.trace),
                    });
                    continue;
                }
                s.trace.steps.push(SearchStep { r: s.r, n });
                match s.policy.step(s.r, n) {
                    Step::Done => {
                        s.trace.converged = true;
                        s.done = Some(FinalCircle {
                            cx: s.cx,
                            cy: s.cy,
                            r: s.r,
                            n_inside: n,
                            trace: std::mem::take(&mut s.trace),
                        });
                    }
                    Step::Settle(rs) => {
                        if rs == s.r {
                            s.trace.converged = true;
                            s.done = Some(FinalCircle {
                                cx: s.cx,
                                cy: s.cy,
                                r: s.r,
                                n_inside: n,
                                trace: std::mem::take(&mut s.trace),
                            });
                        } else {
                            // recount at the settle radius next round
                            s.r = rs;
                            s.recount = true;
                        }
                    }
                    Step::Continue(next) => s.r = next,
                    Step::Exhausted => {
                        s.trace.converged = false;
                        s.done = Some(FinalCircle {
                            cx: s.cx,
                            cy: s.cy,
                            r: s.r,
                            n_inside: n,
                            trace: std::mem::take(&mut s.trace),
                        });
                    }
                }
            }
        }
        Ok(states.into_iter().map(|s| s.done.unwrap()).collect())
    }

    /// Batched classification via [`batch_search`](Self::batch_search).
    pub fn batch_classify(&self, queries: &[Vec<f64>], k: usize) -> Result<Vec<u16>> {
        let circles = self.batch_search(queries, k)?;
        circles
            .iter()
            .map(|c| {
                let (_, cls) = self.count_via_pjrt(c.cx, c.cy, c.r, k)?;
                Ok(cls
                    .iter()
                    .enumerate()
                    .max_by(|a, b| {
                        a.1.partial_cmp(b.1)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(b.0.cmp(&a.0))
                    })
                    .map(|(c, _)| c as u16)
                    .unwrap_or(0))
            })
            .collect()
    }

    /// Candidate extraction through the `neighbor_scan` artifact (falls
    /// back to the native collect when K_MAX or the ladder is exceeded).
    fn candidates(&self, circle: &FinalCircle) -> Result<Vec<scan::Candidate>> {
        let grid = self.inner.grid();
        let metric = self.inner.params().metric;
        if let Some(w) = self.ladder.select(circle.r) {
            let name = format!("neighbor_scan_w{w}");
            if let Some(meta) = self.service.meta(&name) {
                let k_max = meta.k_max as u64;
                // a pixel may hold several points; the artifact ranks
                // pixels, so only use it when every occupied pixel fits
                if circle.n_inside <= k_max {
                    let mut window = vec![0f32; w * w];
                    grid.crop_total_f32(circle.cx, circle.cy, w, &mut window);
                    let out = self.service.neighbor_scan(
                        &name,
                        window,
                        circle.r as f32,
                        metric == Metric::L1,
                    )?;
                    let mut cands = Vec::new();
                    let half = (w / 2) as i64;
                    for (d, &idx) in out.dists.iter().zip(&out.indices) {
                        if idx < 0 || !d.is_finite() {
                            continue;
                        }
                        let wy = idx as i64 / w as i64;
                        let wx = idx as i64 % w as i64;
                        let gx = circle.cx as i64 - half + wx;
                        let gy = circle.cy as i64 - half + wy;
                        if gx < 0
                            || gy < 0
                            || gx >= grid.resolution() as i64
                            || gy >= grid.resolution() as i64
                        {
                            continue;
                        }
                        for pid in grid.points_at(gx as u32, gy as u32) {
                            cands.push(scan::Candidate {
                                point_id: pid,
                                pixel_dist: *d as f64,
                            });
                        }
                    }
                    return Ok(cands);
                }
            }
        }
        Ok(scan::collect_in_disk(grid, circle.cx, circle.cy, circle.r, metric))
    }
}

impl NnEngine for ActivePjrtEngine {
    fn name(&self) -> &'static str {
        "active-pjrt"
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn knn(&self, q: &[f64], k: usize) -> Result<Vec<Neighbor>> {
        Ok(self.knn_stats(q, k)?.0)
    }

    fn knn_stats(&self, q: &[f64], k: usize) -> Result<(Vec<Neighbor>, QueryStats)> {
        let circle = self.search(q, k)?;
        let cands = self.candidates(&circle)?;
        let grid = self.inner.grid();
        let params = self.inner.params();
        let px_len = grid.geometry().pixel_size()[0];
        let data = self.inner.dataset();
        let mut out: Vec<Neighbor> = match params.mode {
            SearchMode::Approx => cands
                .into_iter()
                .map(|c| {
                    let dist = match params.metric {
                        Metric::L2 => c.pixel_dist.sqrt() * px_len,
                        Metric::L1 => c.pixel_dist * px_len,
                    };
                    let label =
                        data.as_ref().map(|d| d.label(c.point_id as usize)).unwrap_or(0);
                    Neighbor { id: c.point_id, dist, label }
                })
                .collect(),
            SearchMode::Refined => {
                let data = data.as_ref().ok_or_else(|| {
                    AsnnError::Query("refined mode requires the dataset".into())
                })?;
                cands
                    .into_iter()
                    .map(|c| {
                        let id = c.point_id as usize;
                        Neighbor {
                            id: c.point_id,
                            dist: data.dist2(id, q).sqrt(),
                            label: data.label(id),
                        }
                    })
                    .collect()
            }
        };
        out.sort_by(|a, b| {
            a.dist
                .partial_cmp(&b.dist)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        out.truncate(k);
        let work: u64 = circle
            .trace
            .steps
            .iter()
            .map(|s| scan::disk_pixels(s.r, params.metric))
            .sum();
        Ok((
            out,
            QueryStats {
                work,
                iterations: circle.trace.iterations() as u32,
                converged: circle.trace.converged,
            },
        ))
    }

    /// Paper classification vote, with per-class counts produced by the
    /// `disk_count` artifact at the final circle.
    fn classify(&self, q: &[f64], k: usize) -> Result<u16> {
        let circle = self.search(q, k)?;
        let (_, class_counts) = self.count_via_pjrt(circle.cx, circle.cy, circle.r, k)?;
        let best = class_counts
            .iter()
            .enumerate()
            .max_by(|a, b| {
                a.1.partial_cmp(b.1)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(b.0.cmp(&a.0))
            })
            .map(|(c, _)| c as u16)
            .unwrap_or(0);
        Ok(best)
    }
}
