//! Datasets: point/label storage, synthetic generators, and I/O.

pub mod io;
pub mod soa;
pub mod synthetic;

use crate::error::{AsnnError, Result};

/// A labeled point set in `dim`-dimensional space, stored row-major
/// (`points[i*dim .. (i+1)*dim]` is point `i`).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub dim: usize,
    pub points: Vec<f64>,
    pub labels: Vec<u16>,
    pub num_classes: usize,
}

impl Dataset {
    /// Build from flat storage, validating shape invariants.
    pub fn new(dim: usize, points: Vec<f64>, labels: Vec<u16>, num_classes: usize) -> Result<Self> {
        if dim == 0 {
            return Err(AsnnError::Data("dim must be > 0".into()));
        }
        if points.len() % dim != 0 {
            return Err(AsnnError::Data(format!(
                "points length {} not divisible by dim {}",
                points.len(),
                dim
            )));
        }
        let n = points.len() / dim;
        if labels.len() != n {
            return Err(AsnnError::Data(format!(
                "labels length {} != point count {n}",
                labels.len()
            )));
        }
        if let Some(&bad) = labels.iter().find(|&&l| l as usize >= num_classes) {
            return Err(AsnnError::Data(format!(
                "label {bad} out of range for {num_classes} classes"
            )));
        }
        Ok(Self { dim, points, labels, num_classes })
    }

    pub fn len(&self) -> usize {
        self.points.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Point `i` as a slice.
    #[inline]
    pub fn point(&self, i: usize) -> &[f64] {
        &self.points[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    pub fn label(&self, i: usize) -> u16 {
        self.labels[i]
    }

    /// Axis-aligned bounding box: (mins, maxs) per dimension.
    pub fn bounds(&self) -> (Vec<f64>, Vec<f64>) {
        let mut mins = vec![f64::INFINITY; self.dim];
        let mut maxs = vec![f64::NEG_INFINITY; self.dim];
        for i in 0..self.len() {
            let p = self.point(i);
            for d in 0..self.dim {
                mins[d] = mins[d].min(p[d]);
                maxs[d] = maxs[d].max(p[d]);
            }
        }
        (mins, maxs)
    }

    /// Squared Euclidean distance between point `i` and query `q`.
    #[inline]
    pub fn dist2(&self, i: usize, q: &[f64]) -> f64 {
        let p = self.point(i);
        let mut s = 0.0;
        for d in 0..self.dim {
            let diff = p[d] - q[d];
            s += diff * diff;
        }
        s
    }

    /// L1 (Manhattan) distance between point `i` and query `q`.
    #[inline]
    pub fn dist_l1(&self, i: usize, q: &[f64]) -> f64 {
        let p = self.point(i);
        let mut s = 0.0;
        for d in 0..self.dim {
            s += (p[d] - q[d]).abs();
        }
        s
    }

    /// Split off the last `n_holdout` points as a query/holdout set.
    pub fn split_holdout(mut self, n_holdout: usize) -> Result<(Dataset, Dataset)> {
        let n = self.len();
        if n_holdout >= n {
            return Err(AsnnError::Data(format!(
                "holdout {n_holdout} >= dataset size {n}"
            )));
        }
        let keep = n - n_holdout;
        let hold_pts = self.points.split_off(keep * self.dim);
        let hold_lbl = self.labels.split_off(keep);
        let train = Dataset::new(self.dim, self.points, self.labels, self.num_classes)?;
        let hold = Dataset::new(self.dim, hold_pts, hold_lbl, self.num_classes)?;
        Ok((train, hold))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            2,
            vec![0.0, 0.0, 1.0, 0.0, 0.0, 2.0],
            vec![0, 1, 2],
            3,
        )
        .unwrap()
    }

    #[test]
    fn shape_validation() {
        assert!(Dataset::new(0, vec![], vec![], 1).is_err());
        assert!(Dataset::new(2, vec![1.0], vec![0], 1).is_err());
        assert!(Dataset::new(2, vec![1.0, 2.0], vec![], 1).is_err());
        assert!(Dataset::new(2, vec![1.0, 2.0], vec![5], 3).is_err());
    }

    #[test]
    fn accessors() {
        let d = tiny();
        assert_eq!(d.len(), 3);
        assert_eq!(d.point(1), &[1.0, 0.0]);
        assert_eq!(d.label(2), 2);
    }

    #[test]
    fn bounds_cover_all_points() {
        let (mins, maxs) = tiny().bounds();
        assert_eq!(mins, vec![0.0, 0.0]);
        assert_eq!(maxs, vec![1.0, 2.0]);
    }

    #[test]
    fn distances() {
        let d = tiny();
        assert_eq!(d.dist2(0, &[3.0, 4.0]), 25.0);
        assert_eq!(d.dist_l1(0, &[3.0, 4.0]), 7.0);
    }

    #[test]
    fn holdout_split() {
        let (train, hold) = tiny().split_holdout(1).unwrap();
        assert_eq!(train.len(), 2);
        assert_eq!(hold.len(), 1);
        assert_eq!(hold.point(0), &[0.0, 2.0]);
        assert!(tiny().split_holdout(3).is_err());
    }
}
