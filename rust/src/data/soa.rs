//! Blocked structure-of-arrays `f32` mirror of a [`Dataset`] for the
//! refined-mode distance kernel.
//!
//! The row-major `Dataset` is ideal for single-point access but the
//! refine step touches hundreds of candidates per query, and on that
//! path we want the compiler to vectorize. The mirror stores points in
//! blocks of [`BLOCK`] with coordinates transposed inside each block:
//! coordinate `d` of point `i` lives at
//!
//! ```text
//! data[((i / BLOCK) * dim + d) * BLOCK + (i % BLOCK)]
//! ```
//!
//! so the 8 lanes of one block sit contiguously per dimension and an
//! 8-wide unrolled loop over fixed-size `[f32; BLOCK]` arrays compiles
//! to straight SIMD on any target with 128/256-bit vectors — no
//! intrinsics, no feature gates. Tail lanes of the last block are
//! padded with `f32::INFINITY` so a full-block scan reports them as
//! infinitely far and they can never enter a top-k heap.
//!
//! `f32` halves the memory traffic of the `f64` source; the precision
//! loss (~1e-7 relative) is far below the pixel-quantization error the
//! active-search circle already carries. The `f64` `Dataset::dist2`
//! remains the oracle every kernel here is tested against.

use crate::data::Dataset;

/// Lanes per block. Eight `f32`s fill one 256-bit vector register.
pub const BLOCK: usize = 8;

/// Blocked SoA `f32` copy of a dataset (see module docs for layout).
#[derive(Debug, Clone)]
pub struct SoaMirror {
    dim: usize,
    len: usize,
    data: Vec<f32>,
}

impl SoaMirror {
    /// Transpose `ds` into blocked SoA layout.
    pub fn build(ds: &Dataset) -> Self {
        let dim = ds.dim;
        let len = ds.len();
        let blocks = len.div_ceil(BLOCK);
        let mut data = vec![f32::INFINITY; blocks * dim * BLOCK];
        for i in 0..len {
            let p = ds.point(i);
            let (b, lane) = (i / BLOCK, i % BLOCK);
            for (d, &coord) in p.iter().enumerate() {
                data[(b * dim + d) * BLOCK + lane] = coord as f32;
            }
        }
        Self { dim, len, data }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of lane blocks (including the padded tail block).
    pub fn n_blocks(&self) -> usize {
        self.len.div_ceil(BLOCK)
    }

    /// Resident bytes of the mirror payload.
    pub fn memory_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    #[inline]
    fn coord(&self, i: usize, d: usize) -> f32 {
        self.data[((i / BLOCK) * self.dim + d) * BLOCK + (i % BLOCK)]
    }

    /// Scalar `f32` oracle: squared L2 distance of point `i` to `q`.
    pub fn dist2_scalar(&self, i: usize, q: &[f32]) -> f32 {
        debug_assert_eq!(q.len(), self.dim);
        let mut acc = 0.0f32;
        for (d, &qd) in q.iter().enumerate() {
            let diff = self.coord(i, d) - qd;
            acc += diff * diff;
        }
        acc
    }

    /// Squared L2 distances of the candidate ids to `q`, 8 lanes at a
    /// time, into a caller-owned buffer (cleared first; steady-state
    /// reuse allocates nothing). `out[j]` corresponds to `ids[j]`.
    ///
    /// The gather into fixed `[f32; BLOCK]` arrays is the only
    /// per-element indexing; the subtract/square/accumulate loops run
    /// over the fixed arrays and auto-vectorize.
    pub fn dist2_ids_into(&self, ids: &[u32], q: &[f32], out: &mut Vec<f32>) {
        assert_eq!(q.len(), self.dim, "query dim mismatch");
        out.clear();
        out.reserve(ids.len());
        let mut chunks = ids.chunks_exact(BLOCK);
        for chunk in &mut chunks {
            let mut acc = [0.0f32; BLOCK];
            for (d, &qd) in q.iter().enumerate() {
                let mut diff = [0.0f32; BLOCK];
                for (lane, &id) in chunk.iter().enumerate() {
                    diff[lane] = self.coord(id as usize, d) - qd;
                }
                for lane in 0..BLOCK {
                    acc[lane] += diff[lane] * diff[lane];
                }
            }
            out.extend_from_slice(&acc);
        }
        for &id in chunks.remainder() {
            out.push(self.dist2_scalar(id as usize, q));
        }
    }

    /// Squared L2 distances of one whole block's 8 lanes to `q`.
    /// Padding lanes report `f32::INFINITY`. This is the sequential
    /// full-scan kernel (dense sweeps, benches).
    pub fn dist2_block_into(&self, block: usize, q: &[f32], out: &mut [f32; BLOCK]) {
        assert_eq!(q.len(), self.dim, "query dim mismatch");
        let base = block * self.dim * BLOCK;
        let mut acc = [0.0f32; BLOCK];
        for (d, &qd) in q.iter().enumerate() {
            let lanes = &self.data[base + d * BLOCK..base + (d + 1) * BLOCK];
            for (a, &l) in acc.iter_mut().zip(lanes) {
                let diff = l - qd;
                *a += diff * diff;
            }
        }
        *out = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};
    use crate::util::rng::Rng;

    fn mirror(n: u64) -> (Dataset, SoaMirror) {
        let ds = generate(&SyntheticSpec::paper_default(n, 901));
        let soa = SoaMirror::build(&ds);
        (ds, soa)
    }

    #[test]
    fn scalar_matches_f64_oracle() {
        let (ds, soa) = mirror(100);
        let q = [0.3, 0.7];
        let qf = [q[0] as f32, q[1] as f32];
        for i in 0..ds.len() {
            let want = ds.dist2(i, &q);
            let got = soa.dist2_scalar(i, &qf) as f64;
            assert!((got - want).abs() < 1e-5, "point {i}: {got} vs {want}");
        }
    }

    #[test]
    fn ids_kernel_matches_scalar_any_subset() {
        let (_, soa) = mirror(97); // non-multiple of BLOCK: remainder path
        let mut rng = Rng::new(902);
        let mut out = Vec::new();
        for case in 0..50 {
            let m = rng.below(40) as usize; // includes empty
            let ids: Vec<u32> = (0..m).map(|_| rng.below(97) as u32).collect();
            let q = [rng.next_f64() as f32, rng.next_f64() as f32];
            soa.dist2_ids_into(&ids, &q, &mut out);
            assert_eq!(out.len(), ids.len(), "case {case}");
            for (j, &id) in ids.iter().enumerate() {
                let want = soa.dist2_scalar(id as usize, &q);
                assert_eq!(out[j], want, "case {case} id {id}");
            }
        }
    }

    #[test]
    fn block_kernel_pads_tail_with_infinity() {
        let (ds, soa) = mirror(11); // 2 blocks, 5 padded lanes
        assert_eq!(soa.n_blocks(), 2);
        let q = [0.5f32, 0.5f32];
        let mut out = [0.0f32; BLOCK];
        soa.dist2_block_into(1, &q, &mut out);
        for (lane, &d) in out.iter().enumerate() {
            let i = BLOCK + lane;
            if i < ds.len() {
                assert!(d.is_finite(), "lane {lane} should be real");
                assert_eq!(d, soa.dist2_scalar(i, &q));
            } else {
                assert_eq!(d, f32::INFINITY, "padding lane {lane} must be inert");
            }
        }
    }

    #[test]
    fn empty_dataset_builds_and_answers() {
        let ds = Dataset::new(2, vec![], vec![], 1).unwrap();
        let soa = SoaMirror::build(&ds);
        assert!(soa.is_empty());
        assert_eq!(soa.n_blocks(), 0);
        let mut out = vec![1.0f32];
        soa.dist2_ids_into(&[], &[0.0, 0.0], &mut out);
        assert!(out.is_empty());
    }
}
