//! Dataset I/O: a simple CSV form (`x0,x1,...,label` per line) and a
//! compact little-endian binary form for large benchmark datasets.
//!
//! All writes are crash-safe via [`store::atomic_write`] — a reader
//! never observes a half-written file. The binary form v2 (`ASNNDS02`)
//! wraps the payload in the store's checksummed frame so corruption is
//! detected before any allocation happens; the unframed v1 (`ASNNDS01`)
//! is still readable, with declared row/dim counts validated against
//! the actual byte count so a corrupt header can't trigger a huge
//! allocation or a short-read panic.

use std::fs;
use std::io::{BufRead, BufReader};
use std::path::Path;

use super::Dataset;
use crate::error::{AsnnError, Result};
use crate::store::{self, ByteReader, ByteWriter};

/// Write CSV: header `# dim=<d> classes=<c>` then one line per point.
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    let mut out = String::with_capacity(ds.len() * 24 + 32);
    out.push_str(&format!("# dim={} classes={}\n", ds.dim, ds.num_classes));
    for i in 0..ds.len() {
        for v in ds.point(i) {
            out.push_str(&format!("{v},"));
        }
        out.push_str(&format!("{}\n", ds.label(i)));
    }
    store::atomic_write(path, out.as_bytes())
}

/// Read the CSV form written by [`save_csv`].
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let r = BufReader::new(fs::File::open(path)?);
    let mut dim = 0usize;
    let mut classes = 0usize;
    let mut points = Vec::new();
    let mut labels = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(hdr) = line.strip_prefix('#') {
            for tok in hdr.split_whitespace() {
                if let Some(v) = tok.strip_prefix("dim=") {
                    dim = v.parse().map_err(|_| bad_line(lineno, "dim"))?;
                } else if let Some(v) = tok.strip_prefix("classes=") {
                    classes = v.parse().map_err(|_| bad_line(lineno, "classes"))?;
                }
            }
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if dim == 0 {
            dim = fields.len() - 1;
        }
        if fields.len() != dim + 1 {
            return Err(bad_line(lineno, "field count"));
        }
        for f in &fields[..dim] {
            points.push(f.parse::<f64>().map_err(|_| bad_line(lineno, "coordinate"))?);
        }
        labels.push(fields[dim].parse::<u16>().map_err(|_| bad_line(lineno, "label"))?);
    }
    if classes == 0 {
        classes = labels.iter().map(|&l| l as usize + 1).max().unwrap_or(1);
    }
    Dataset::new(dim, points, labels, classes)
}

fn bad_line(lineno: usize, what: &str) -> AsnnError {
    AsnnError::Data(format!("csv line {}: bad {what}", lineno + 1))
}

/// Legacy unframed binary magic (v1): no checksum, read-only support.
const BIN_MAGIC_V1: &[u8; 8] = b"ASNNDS01";
/// Current framed binary magic (v2): CRC32 + length footer via `store`.
pub const BIN_MAGIC: &[u8; 8] = b"ASNNDS02";

/// Bytes of the fixed body header: dim, classes, n as u64 LE.
const BODY_HEADER: usize = 24;

/// Serialize to the v2 binary image (checksummed frame included).
/// Body layout after the frame magic: `dim`/`classes`/`n` as u64 LE,
/// then `n·dim` f64 points, then `n` u16 labels. These are exactly the
/// bytes [`save_bin`] puts on disk, and also the payload the
/// coordinator's snapshotter stores as a dataset generation.
pub fn dataset_to_bytes(ds: &Dataset) -> Vec<u8> {
    let mut w = ByteWriter::with_capacity(BODY_HEADER + ds.points.len() * 8 + ds.labels.len() * 2);
    w.u64(ds.dim as u64);
    w.u64(ds.num_classes as u64);
    w.u64(ds.len() as u64);
    for &p in &ds.points {
        w.f64(p);
    }
    for &l in &ds.labels {
        w.u16(l);
    }
    store::encode_framed(BIN_MAGIC, &w.into_vec())
}

/// Parse a binary dataset image — v2 (checksum-verified) or legacy v1.
pub fn dataset_from_bytes(bytes: &[u8]) -> Result<Dataset> {
    if bytes.len() < 8 {
        return Err(AsnnError::Data(format!(
            "file too short for a dataset magic ({} bytes)",
            bytes.len()
        )));
    }
    if &bytes[..8] == BIN_MAGIC {
        dataset_body(store::decode_framed(BIN_MAGIC, bytes)?)
    } else if &bytes[..8] == BIN_MAGIC_V1 {
        dataset_body(&bytes[8..])
    } else {
        Err(AsnnError::Data("bad magic: not an asnn dataset".into()))
    }
}

/// Decode the shared v1/v2 body. The declared `n`/`dim`/`classes` are
/// validated against the actual body length *before* any allocation,
/// so a corrupt or hostile header cannot request gigabytes or walk off
/// the end of a short file.
fn dataset_body(body: &[u8]) -> Result<Dataset> {
    let mut r = ByteReader::new(body);
    let dim = r.u64()? as usize;
    let classes = r.u64()? as usize;
    let n = r.u64()? as usize;
    let overflow = || AsnnError::Data(format!("dataset header overflows: n={n} dim={dim}"));
    let point_bytes = n
        .checked_mul(dim)
        .and_then(|v| v.checked_mul(8))
        .ok_or_else(overflow)?;
    let need = n
        .checked_mul(2)
        .and_then(|v| v.checked_add(point_bytes))
        .and_then(|v| v.checked_add(BODY_HEADER))
        .ok_or_else(overflow)?;
    if need != body.len() {
        return Err(AsnnError::Data(format!(
            "dataset size mismatch: header declares n={n} dim={dim} ({need} bytes), body has {}",
            body.len()
        )));
    }
    let mut points = Vec::with_capacity(n * dim);
    for chunk in r.take(point_bytes)?.chunks_exact(8) {
        points.push(f64::from_le_bytes(chunk.try_into().unwrap()));
    }
    let mut labels = Vec::with_capacity(n);
    for chunk in r.take(n * 2)?.chunks_exact(2) {
        labels.push(u16::from_le_bytes(chunk.try_into().unwrap()));
    }
    r.finish()?;
    Dataset::new(dim, points, labels, classes)
}

/// Write the v2 binary form atomically (torn writes are impossible;
/// corruption after the fact is caught by the CRC on load).
pub fn save_bin(ds: &Dataset, path: &Path) -> Result<()> {
    store::atomic_write(path, &dataset_to_bytes(ds))
}

/// Read the binary form written by [`save_bin`] (v2) or by older
/// releases (v1, unframed).
pub fn load_bin(path: &Path) -> Result<Dataset> {
    dataset_from_bytes(&fs::read(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("asnn-io-test-{}-{name}", std::process::id()));
        p
    }

    #[test]
    fn csv_roundtrip() {
        let ds = generate(&SyntheticSpec::paper_default(50, 3));
        let path = tmp("a.csv");
        save_csv(&ds, &path).unwrap();
        let back = load_csv(&path).unwrap();
        assert_eq!(back.dim, ds.dim);
        assert_eq!(back.num_classes, ds.num_classes);
        assert_eq!(back.labels, ds.labels);
        for (a, b) in back.points.iter().zip(&ds.points) {
            assert!((a - b).abs() < 1e-12);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bin_roundtrip_exact() {
        let ds = generate(&SyntheticSpec::blobs(64, 3, 5));
        let path = tmp("b.bin");
        save_bin(&ds, &path).unwrap();
        let back = load_bin(&path).unwrap();
        assert_eq!(back.points, ds.points); // bit-exact
        assert_eq!(back.labels, ds.labels);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("c.bin");
        std::fs::write(&path, b"NOTADATASET....").unwrap();
        assert!(load_bin(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_bad_line_reports_lineno() {
        let path = tmp("d.csv");
        std::fs::write(&path, "# dim=2 classes=2\n0.1,0.2,0\n0.3,oops,1\n").unwrap();
        let err = load_csv(&path).unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn legacy_v1_still_loads() {
        let ds = generate(&SyntheticSpec::blobs(16, 2, 4));
        // reconstruct the v1 image: v1 magic + the (unframed) v2 body
        let v2 = dataset_to_bytes(&ds);
        let body = store::decode_framed(BIN_MAGIC, &v2).unwrap();
        let mut v1 = BIN_MAGIC_V1.to_vec();
        v1.extend_from_slice(body);
        let path = tmp("e.bin");
        std::fs::write(&path, &v1).unwrap();
        let back = load_bin(&path).unwrap();
        assert_eq!(back.points, ds.points);
        assert_eq!(back.labels, ds.labels);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncation_at_every_byte_rejected() {
        let ds = generate(&SyntheticSpec::blobs(8, 2, 3));
        let full = dataset_to_bytes(&ds);
        for cut in 0..full.len() {
            assert!(
                dataset_from_bytes(&full[..cut]).is_err(),
                "truncated dataset ({cut}/{} bytes) accepted",
                full.len()
            );
        }
        assert!(dataset_from_bytes(&full).is_ok());
    }

    #[test]
    fn hostile_header_cannot_demand_huge_allocation() {
        // v1 has no checksum, so a corrupt header reaches the size
        // check directly: declare 2^56 points backed by 12 bytes.
        let mut bytes = BIN_MAGIC_V1.to_vec();
        for v in [2u64, 3, 1u64 << 56] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[0u8; 12]);
        let err = dataset_from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("mismatch") || err.contains("overflow"), "{err}");
    }

    #[test]
    fn short_v1_body_is_error_not_panic() {
        // header says 4 points but the points array is cut short
        let mut bytes = BIN_MAGIC_V1.to_vec();
        for v in [2u64, 2, 4] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[0u8; 16]); // 2 of 64 point bytes
        assert!(dataset_from_bytes(&bytes).is_err());
    }

    #[test]
    fn no_staging_file_left_behind() {
        let ds = generate(&SyntheticSpec::blobs(8, 2, 3));
        let path = tmp("f.bin");
        save_bin(&ds, &path).unwrap();
        let staged = tmp("f.bin.tmp");
        assert!(!staged.exists());
        std::fs::remove_file(path).ok();
    }
}
