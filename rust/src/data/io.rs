//! Dataset I/O: a simple CSV form (`x0,x1,...,label` per line) and a
//! compact little-endian binary form for large benchmark datasets.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::Dataset;
use crate::error::{AsnnError, Result};

/// Write CSV: header `# dim=<d> classes=<c>` then one line per point.
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# dim={} classes={}", ds.dim, ds.num_classes)?;
    for i in 0..ds.len() {
        let p = ds.point(i);
        for v in p {
            write!(w, "{v},")?;
        }
        writeln!(w, "{}", ds.label(i))?;
    }
    w.flush()?;
    Ok(())
}

/// Read the CSV form written by [`save_csv`].
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let r = BufReader::new(File::open(path)?);
    let mut dim = 0usize;
    let mut classes = 0usize;
    let mut points = Vec::new();
    let mut labels = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(hdr) = line.strip_prefix('#') {
            for tok in hdr.split_whitespace() {
                if let Some(v) = tok.strip_prefix("dim=") {
                    dim = v.parse().map_err(|_| bad_line(lineno, "dim"))?;
                } else if let Some(v) = tok.strip_prefix("classes=") {
                    classes = v.parse().map_err(|_| bad_line(lineno, "classes"))?;
                }
            }
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if dim == 0 {
            dim = fields.len() - 1;
        }
        if fields.len() != dim + 1 {
            return Err(bad_line(lineno, "field count"));
        }
        for f in &fields[..dim] {
            points.push(f.parse::<f64>().map_err(|_| bad_line(lineno, "coordinate"))?);
        }
        labels.push(fields[dim].parse::<u16>().map_err(|_| bad_line(lineno, "label"))?);
    }
    if classes == 0 {
        classes = labels.iter().map(|&l| l as usize + 1).max().unwrap_or(1);
    }
    Dataset::new(dim, points, labels, classes)
}

fn bad_line(lineno: usize, what: &str) -> AsnnError {
    AsnnError::Data(format!("csv line {}: bad {what}", lineno + 1))
}

const BIN_MAGIC: &[u8; 8] = b"ASNNDS01";

/// Binary form: magic, dim/classes/n as u64 LE, then f64 points, u16 labels.
pub fn save_bin(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    for v in [ds.dim as u64, ds.num_classes as u64, ds.len() as u64] {
        w.write_all(&v.to_le_bytes())?;
    }
    for &p in &ds.points {
        w.write_all(&p.to_le_bytes())?;
    }
    for &l in &ds.labels {
        w.write_all(&l.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read the binary form written by [`save_bin`].
pub fn load_bin(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        return Err(AsnnError::Data("bad magic: not an asnn dataset".into()));
    }
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<File>| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let dim = read_u64(&mut r)? as usize;
    let classes = read_u64(&mut r)? as usize;
    let n = read_u64(&mut r)? as usize;
    let mut points = vec![0f64; n * dim];
    let mut buf8 = [0u8; 8];
    for p in points.iter_mut() {
        r.read_exact(&mut buf8)?;
        *p = f64::from_le_bytes(buf8);
    }
    let mut labels = vec![0u16; n];
    let mut buf2 = [0u8; 2];
    for l in labels.iter_mut() {
        r.read_exact(&mut buf2)?;
        *l = u16::from_le_bytes(buf2);
    }
    Dataset::new(dim, points, labels, classes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticSpec};

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("asnn-io-test-{}-{name}", std::process::id()));
        p
    }

    #[test]
    fn csv_roundtrip() {
        let ds = generate(&SyntheticSpec::paper_default(50, 3));
        let path = tmp("a.csv");
        save_csv(&ds, &path).unwrap();
        let back = load_csv(&path).unwrap();
        assert_eq!(back.dim, ds.dim);
        assert_eq!(back.num_classes, ds.num_classes);
        assert_eq!(back.labels, ds.labels);
        for (a, b) in back.points.iter().zip(&ds.points) {
            assert!((a - b).abs() < 1e-12);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bin_roundtrip_exact() {
        let ds = generate(&SyntheticSpec::blobs(64, 3, 5));
        let path = tmp("b.bin");
        save_bin(&ds, &path).unwrap();
        let back = load_bin(&path).unwrap();
        assert_eq!(back.points, ds.points); // bit-exact
        assert_eq!(back.labels, ds.labels);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("c.bin");
        std::fs::write(&path, b"NOTADATASET....").unwrap();
        assert!(load_bin(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_bad_line_reports_lineno() {
        let path = tmp("d.csv");
        std::fs::write(&path, "# dim=2 classes=2\n0.1,0.2,0\n0.3,oops,1\n").unwrap();
        let err = load_csv(&path).unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
        std::fs::remove_file(path).ok();
    }
}
