//! Synthetic workload generators.
//!
//! The paper evaluates on "randomly generated 2 dimensional data points"
//! with 3 classes ([§3]). We provide that workload
//! ([`SyntheticSpec::paper_default`]) plus Gaussian-mixture blobs (used
//! for the Fig. 2-style illustrations, where classes are spatially
//! clustered) and rings (a worst case for LSH).

use super::Dataset;
use crate::util::rng::Rng;

/// Distribution family for generated points.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// i.i.d. uniform in the unit hypercube; labels uniform at random —
    /// the paper's "no class structure" worst case.
    Uniform,
    /// One isotropic Gaussian blob per class, centers on a circle.
    Blobs,
    /// Concentric rings, one per class (hard for hash/tree baselines).
    Rings,
}

impl Family {
    pub fn parse(s: &str) -> Option<Family> {
        match s {
            "uniform" => Some(Family::Uniform),
            "blobs" => Some(Family::Blobs),
            "rings" => Some(Family::Rings),
            _ => None,
        }
    }
}

/// Full generator specification.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub family: Family,
    pub n: usize,
    pub dim: usize,
    pub num_classes: usize,
    pub seed: u64,
    /// Blob standard deviation (fraction of unit box).
    pub blob_std: f64,
}

impl SyntheticSpec {
    /// The paper's §3 workload: uniform 2-D, 3 classes.
    pub fn paper_default(n: usize, seed: u64) -> Self {
        Self { family: Family::Uniform, n, dim: 2, num_classes: 3, seed, blob_std: 0.06 }
    }

    pub fn blobs(n: usize, num_classes: usize, seed: u64) -> Self {
        Self { family: Family::Blobs, n, dim: 2, num_classes, seed, blob_std: 0.06 }
    }

    pub fn rings(n: usize, num_classes: usize, seed: u64) -> Self {
        Self { family: Family::Rings, n, dim: 2, num_classes, seed, blob_std: 0.02 }
    }
}

/// Generate a dataset from a spec. Points land in the unit hypercube
/// `[0,1]^dim` (clamped for blob/ring tails) so grid bounds are stable.
pub fn generate(spec: &SyntheticSpec) -> Dataset {
    let mut rng = Rng::new(spec.seed);
    let mut points = Vec::with_capacity(spec.n * spec.dim);
    let mut labels = Vec::with_capacity(spec.n);
    match spec.family {
        Family::Uniform => {
            for _ in 0..spec.n {
                for _ in 0..spec.dim {
                    points.push(rng.next_f64());
                }
                labels.push(rng.below(spec.num_classes as u64) as u16);
            }
        }
        Family::Blobs => {
            // class centers evenly spaced on a circle of radius 0.3
            let centers: Vec<Vec<f64>> = (0..spec.num_classes)
                .map(|c| {
                    let ang = c as f64 / spec.num_classes as f64 * std::f64::consts::TAU;
                    let mut ctr = vec![0.5; spec.dim];
                    ctr[0] = 0.5 + 0.3 * ang.cos();
                    if spec.dim > 1 {
                        ctr[1] = 0.5 + 0.3 * ang.sin();
                    }
                    ctr
                })
                .collect();
            for _ in 0..spec.n {
                let c = rng.below(spec.num_classes as u64) as usize;
                for d in 0..spec.dim {
                    let x = rng.normal_with(centers[c][d], spec.blob_std);
                    points.push(x.clamp(0.0, 1.0));
                }
                labels.push(c as u16);
            }
        }
        Family::Rings => {
            for _ in 0..spec.n {
                let c = rng.below(spec.num_classes as u64) as usize;
                let radius = 0.12 + 0.33 * (c as f64 + 0.5) / spec.num_classes as f64;
                let ang = rng.uniform(0.0, std::f64::consts::TAU);
                let noise = rng.normal_with(0.0, spec.blob_std);
                let r = radius + noise;
                let mut p = vec![0.5; spec.dim];
                p[0] = (0.5 + r * ang.cos()).clamp(0.0, 1.0);
                if spec.dim > 1 {
                    p[1] = (0.5 + r * ang.sin()).clamp(0.0, 1.0);
                }
                points.extend_from_slice(&p);
                labels.push(c as u16);
            }
        }
    }
    Dataset::new(spec.dim, points, labels, spec.num_classes).expect("generator invariant")
}

/// Generate `n` query points matching the spec's support (uniform in the
/// unit box for all families — the paper classifies 100 fresh points).
pub fn generate_queries(n: usize, dim: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed ^ 0xA5A5_A5A5_A5A5_A5A5);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_f64()).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_shape_and_range() {
        let ds = generate(&SyntheticSpec::paper_default(1000, 1));
        assert_eq!(ds.len(), 1000);
        assert_eq!(ds.dim, 2);
        assert_eq!(ds.num_classes, 3);
        assert!(ds.points.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn uniform_label_balance() {
        let ds = generate(&SyntheticSpec::paper_default(30_000, 2));
        let mut counts = [0usize; 3];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / 30_000.0;
            assert!((frac - 1.0 / 3.0).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&SyntheticSpec::paper_default(100, 9));
        let b = generate(&SyntheticSpec::paper_default(100, 9));
        assert_eq!(a.points, b.points);
        assert_eq!(a.labels, b.labels);
        let c = generate(&SyntheticSpec::paper_default(100, 10));
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn blobs_cluster_near_centers() {
        let ds = generate(&SyntheticSpec::blobs(3000, 3, 4));
        // each class's mean point should be far from the global center
        for class in 0..3u16 {
            let (mut mx, mut my, mut n) = (0.0, 0.0, 0);
            for i in 0..ds.len() {
                if ds.label(i) == class {
                    mx += ds.point(i)[0];
                    my += ds.point(i)[1];
                    n += 1;
                }
            }
            let (mx, my) = (mx / n as f64, my / n as f64);
            let dist = ((mx - 0.5).powi(2) + (my - 0.5).powi(2)).sqrt();
            assert!((dist - 0.3).abs() < 0.05, "class {class} center dist {dist}");
        }
    }

    #[test]
    fn rings_have_distinct_radii() {
        let ds = generate(&SyntheticSpec::rings(3000, 3, 8));
        let mut mean_r = [0.0f64; 3];
        let mut counts = [0usize; 3];
        for i in 0..ds.len() {
            let p = ds.point(i);
            let r = ((p[0] - 0.5).powi(2) + (p[1] - 0.5).powi(2)).sqrt();
            mean_r[ds.label(i) as usize] += r;
            counts[ds.label(i) as usize] += 1;
        }
        for c in 0..3 {
            mean_r[c] /= counts[c] as f64;
        }
        assert!(mean_r[0] < mean_r[1] && mean_r[1] < mean_r[2], "{mean_r:?}");
    }

    #[test]
    fn queries_deterministic() {
        let a = generate_queries(10, 2, 1);
        let b = generate_queries(10, 2, 1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 10);
        assert_eq!(a[0].len(), 2);
    }

    #[test]
    fn family_parse() {
        assert_eq!(Family::parse("uniform"), Some(Family::Uniform));
        assert_eq!(Family::parse("blobs"), Some(Family::Blobs));
        assert_eq!(Family::parse("rings"), Some(Family::Rings));
        assert_eq!(Family::parse("nope"), None);
    }
}
