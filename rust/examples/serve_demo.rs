//! Minimal end-to-end serving demo, also used by CI's STATS2 schema
//! check: boots a tiny stack (brute + active with a shared
//! observability recorder), serves a handful of KNN queries and a
//! TRACE over real TCP, then prints the `STATS2 json` document —
//! and nothing else — to stdout so a schema assertion can parse it.
//!
//! Run: `cargo run --release --example serve_demo`

use std::sync::Arc;

use asnn::coordinator::server::Client;
use asnn::coordinator::{Metrics, Request, Response, Router, Server, StatsFormat};
use asnn::data::synthetic::{generate, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;
use asnn::obs::Recorder;

fn main() {
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(2000, 7)));

    // the demo mirrors cmd_serve's wiring: one recorder shared by the
    // active engine (stage spans) and the router (engine counters)
    let recorder = Arc::new(Recorder::new());
    let mut active = ActiveEngine::new(ds.clone(), 256, ActiveParams::default()).unwrap();
    active.set_recorder(Arc::clone(&recorder));

    let mut router = Router::new("active", Arc::new(Metrics::new()));
    router.set_recorder(recorder);
    router.register_engine(Arc::new(BruteEngine::new(ds.clone())));
    router.register_engine(Arc::new(active));

    let handle = Server::new(Arc::new(router), 2).spawn("127.0.0.1:0").unwrap();
    eprintln!("serve_demo: listening on {}", handle.addr);

    let mut c = Client::connect(&handle.addr).unwrap();
    for (x, y) in [(0.2, 0.3), (0.5, 0.5), (0.8, 0.4), (0.3, 0.7)] {
        match c.call(&Request::Knn { k: 11, x, y, engine: None }).unwrap() {
            Response::Neighbors(hits) => {
                eprintln!("serve_demo: knn ({x},{y}) -> {} hits", hits.len())
            }
            other => panic!("unexpected KNN response: {other:?}"),
        }
    }
    match c
        .call(&Request::Trace { k: 5, x: 0.5, y: 0.5, engine: Some("active".into()) })
        .unwrap()
    {
        Response::Text(t) => eprintln!("serve_demo: trace {t}"),
        other => panic!("unexpected TRACE response: {other:?}"),
    }

    // stdout carries exactly the STATS2 JSON document
    match c.call(&Request::Stats2 { format: StatsFormat::Json, section: None }).unwrap() {
        Response::Text(json) => println!("{json}"),
        other => panic!("unexpected STATS2 response: {other:?}"),
    }

    drop(c);
    handle.shutdown();
}
