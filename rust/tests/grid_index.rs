//! Integration: grid index invariants across datasets, resolutions,
//! and the scan primitives (counts must be conserved everywhere).

use asnn::config::Metric;
use asnn::active::scan;
use asnn::data::synthetic::{generate, SyntheticSpec};
use asnn::grid::{MultiGrid, Pyramid};

#[test]
fn counts_conserved_across_resolutions() {
    let ds = generate(&SyntheticSpec::paper_default(5000, 401));
    for &res in &[64usize, 256, 1000, 3000] {
        let g = MultiGrid::build(&ds, res).unwrap();
        let total: u64 = (0..res as u32)
            .map(|y| g.total_row(y).iter().map(|&v| v as u64).sum::<u64>())
            .sum();
        assert_eq!(total, 5000, "res {res}");
    }
}

#[test]
fn full_disk_scan_counts_everything_all_families() {
    for spec in [
        SyntheticSpec::paper_default(2000, 402),
        SyntheticSpec::blobs(2000, 3, 403),
        SyntheticSpec::rings(2000, 3, 404),
    ] {
        let ds = generate(&spec);
        let g = MultiGrid::build(&ds, 300).unwrap();
        let n = scan::count_in_disk(&g, 150, 150, 600, Metric::L2);
        assert_eq!(n, 2000, "{:?}", spec.family);
    }
}

#[test]
fn disk_monotone_in_radius() {
    let ds = generate(&SyntheticSpec::paper_default(3000, 405));
    let g = MultiGrid::build(&ds, 500).unwrap();
    let mut last = 0;
    for r in (0..250).step_by(10) {
        let n = scan::count_in_disk(&g, 250, 250, r, Metric::L2);
        assert!(n >= last, "r={r}: {n} < {last}");
        last = n;
    }
}

#[test]
fn pyramid_consistent_with_grid() {
    let ds = generate(&SyntheticSpec::blobs(4000, 3, 406));
    let g = MultiGrid::build(&ds, 512).unwrap();
    let p = Pyramid::build(&g);
    // coarse count at any level bounds the fine pixel count from above
    for &(px, py) in &[(100u32, 100u32), (256, 256), (500, 30)] {
        let fine = g.count_at(px, py) as u32;
        for level in 0..p.num_levels() {
            assert!(p.count_at(level, px, py) >= fine);
        }
    }
}

#[test]
fn collect_candidates_have_valid_ids_and_distances() {
    let ds = generate(&SyntheticSpec::paper_default(1500, 407));
    let g = MultiGrid::build(&ds, 400).unwrap();
    let cands = scan::collect_in_disk(&g, 200, 200, 80, Metric::L2);
    for c in &cands {
        assert!((c.point_id as usize) < ds.len());
        assert!(c.pixel_dist <= 80.0 * 80.0);
        // the candidate's true pixel really is in the circle
        let p = ds.point(c.point_id as usize);
        let (px, py) = g.geometry().pixel_of(p[0], p[1]);
        let dx = px as i64 - 200;
        let dy = py as i64 - 200;
        assert!(dx * dx + dy * dy <= 80 * 80);
    }
}

#[test]
fn large_dataset_grid_build_is_complete() {
    let ds = generate(&SyntheticSpec::paper_default(200_000, 408));
    let g = MultiGrid::build(&ds, 3000).unwrap();
    assert_eq!(g.n_points(), 200_000);
    // memory model: 2 B total + 2 B·C classes + 4 B row-prefix per
    // pixel, plus 8 B bucket + 2 B label per point
    let expect =
        3000 * 3000 * 2 + 3000 * 3000 * 3 * 2 + 3000 * 3001 * 4 + 200_000 * 8 + 200_000 * 2;
    assert_eq!(g.memory_bytes(), expect);
}
