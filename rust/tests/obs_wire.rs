//! End-to-end observability wire tests: the STATS2 schema over real
//! TCP, TRACE span-tree invariants against a live server, and the
//! frozen legacy STATS shim.

use std::sync::Arc;

use asnn::coordinator::server::Client;
use asnn::coordinator::{Metrics, Request, Response, Router, Server, StatsFormat};
use asnn::data::synthetic::{generate, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;
use asnn::obs::{Json, Recorder};

/// Server wiring as `asnn serve` does it: one recorder shared by the
/// active engine (stage spans) and the router (engine counters).
fn obs_router(n: usize, seed: u64) -> Router {
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(n, seed)));
    let recorder = Arc::new(Recorder::new());
    let mut active = ActiveEngine::new(ds.clone(), 256, ActiveParams::default()).unwrap();
    active.set_recorder(Arc::clone(&recorder));
    let mut router = Router::new("active", Arc::new(Metrics::new()));
    router.set_recorder(recorder);
    router.register_engine(Arc::new(BruteEngine::new(ds)));
    router.register_engine(Arc::new(active));
    router
}

fn text(resp: Response) -> String {
    match resp {
        Response::Text(t) => t,
        other => panic!("expected text response, got {other:?}"),
    }
}

#[test]
fn stats2_json_schema_over_tcp() {
    let handle = Server::new(Arc::new(obs_router(3000, 701)), 2)
        .spawn("127.0.0.1:0")
        .unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    for (x, y) in [(0.3, 0.4), (0.6, 0.6), (0.5, 0.2)] {
        match c.call(&Request::Knn { k: 11, x, y, engine: None }).unwrap() {
            Response::Neighbors(hits) => assert!(!hits.is_empty()),
            other => panic!("{other:?}"),
        }
    }
    let raw = text(
        c.call(&Request::Stats2 { format: StatsFormat::Json, section: None }).unwrap(),
    );
    let doc = Json::parse(&raw).unwrap();
    assert_eq!(doc.get("v").and_then(Json::as_u64), Some(2), "{raw}");

    // every stage appears with a latency histogram; the active engine
    // self-reported its coarse radius loop and disk scan
    let stages = doc.get("stages").expect("stages section");
    for name in ["coarse", "refine", "scan", "retry", "hedge", "batch_wait"] {
        let stage = stages.get(name).unwrap_or_else(|| panic!("missing stage {name}"));
        assert!(stage.get("count").and_then(Json::as_u64).is_some(), "{name}");
        assert!(stage.get("p50_ns").and_then(Json::as_u64).is_some(), "{name}");
    }
    assert!(stages.get("coarse").unwrap().get("count").and_then(Json::as_u64).unwrap() >= 3);
    assert!(stages.get("scan").unwrap().get("count").and_then(Json::as_u64).unwrap() >= 3);

    // per-engine counters: the default chain settled on "active"
    let active = doc.get("engines").and_then(|e| e.get("active")).expect("engines.active");
    assert!(active.get("requests").and_then(Json::as_u64).unwrap() >= 3);
    assert_eq!(active.get("errors").and_then(Json::as_u64), Some(0));

    // coordinator section mirrors the legacy counters
    let coord = doc.get("coordinator").expect("coordinator section");
    assert_eq!(coord.get("knn_requests").and_then(Json::as_u64), Some(3));

    handle.shutdown();
}

#[test]
fn trace_span_tree_over_tcp() {
    let handle = Server::new(Arc::new(obs_router(3000, 702)), 2)
        .spawn("127.0.0.1:0")
        .unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let raw = text(
        c.call(&Request::Trace { k: 7, x: 0.4, y: 0.6, engine: Some("active".into()) })
            .unwrap(),
    );
    let doc = Json::parse(&raw).unwrap();
    assert_eq!(doc.get("v").and_then(Json::as_u64), Some(1), "{raw}");
    assert_eq!(doc.get("engine").and_then(Json::as_str), Some("active"));
    assert!(doc.get("neighbors").and_then(Json::as_u64).unwrap() >= 1);

    // span tree: request → engine:active → stage leaves, durations
    // nested (leaf sum ≤ engine ≤ request)
    let root = doc.get("root").expect("root span");
    assert_eq!(root.get("name").and_then(Json::as_str), Some("request"));
    let total_ns = root.get("dur_ns").and_then(Json::as_u64).unwrap();
    let engine_span = &root.get("children").and_then(Json::as_arr).unwrap()[0];
    assert_eq!(engine_span.get("name").and_then(Json::as_str), Some("engine:active"));
    let engine_ns = engine_span.get("dur_ns").and_then(Json::as_u64).unwrap();
    let leaves = engine_span.get("children").and_then(Json::as_arr).unwrap();
    assert!(!leaves.is_empty(), "{raw}");
    let leaf_sum: u64 =
        leaves.iter().map(|l| l.get("dur_ns").and_then(Json::as_u64).unwrap()).sum();
    assert!(
        leaf_sum <= engine_ns && engine_ns <= total_ns,
        "span nesting violated: leaves={leaf_sum} engine={engine_ns} total={total_ns}"
    );
    let names: Vec<&str> =
        leaves.iter().filter_map(|l| l.get("name").and_then(Json::as_str)).collect();
    assert!(names.contains(&"coarse"), "{names:?}");
    assert!(names.contains(&"scan"), "{names:?}");

    handle.shutdown();
}

#[test]
fn legacy_stats_shim_is_frozen() {
    let handle = Server::new(Arc::new(obs_router(2000, 703)), 2)
        .spawn("127.0.0.1:0")
        .unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    for (x, y) in [(0.3, 0.4), (0.7, 0.2)] {
        c.call(&Request::Knn { k: 5, x, y, engine: None }).unwrap();
    }
    c.call(&Request::Classify { k: 5, x: 0.5, y: 0.5, engine: None }).unwrap();

    let raw = text(c.call(&Request::Stats).unwrap());
    // the one-line key=value format is a compatibility contract: same
    // keys, same order, forever (STATS2 is where the schema grows)
    let keys: Vec<&str> =
        raw.split_whitespace().map(|kv| kv.split('=').next().unwrap()).collect();
    assert_eq!(
        keys,
        [
            "knn", "classify", "errors", "batches", "batched", "expired_dropped",
            "accept_errors", "shed", "timeouts", "retries", "trips", "fallbacks",
            "panics", "hedges", "hedge_wins", "budget_exhausted", "oversize_rejected",
            "idle_disconnects", "write_timeout_disconnects", "corrupt_quarantined",
            "snapshots", "snapshot_failures", "knn_mean_us", "knn_p50_us", "knn_p99_us",
            "classify_mean_us", "classify_p99_us",
        ],
        "legacy STATS keys drifted: {raw}"
    );
    assert!(raw.starts_with("knn=2 classify=1 "), "{raw}");

    handle.shutdown();
}
