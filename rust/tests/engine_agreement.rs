//! Cross-engine integration: every engine approximates the brute-force
//! ground truth on the paper's workload, and the exact engines agree
//! perfectly.

use std::sync::Arc;

use asnn::config::{R0Policy, SearchMode};
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;
use asnn::engine::kdtree::KdTreeEngine;
use asnn::engine::lsh::{LshEngine, LshParams};
use asnn::engine::{Neighbor, NnEngine};

fn recall(hits: &[Neighbor], truth: &[Neighbor]) -> f64 {
    let ids: Vec<u32> = truth.iter().map(|n| n.id).collect();
    hits.iter().filter(|h| ids.contains(&h.id)).count() as f64 / truth.len() as f64
}

#[test]
fn kdtree_is_exact() {
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(3000, 301)));
    let brute = BruteEngine::new(ds.clone());
    let kd = KdTreeEngine::build(ds);
    for q in generate_queries(25, 2, 302) {
        let t = brute.knn(&q, 11).unwrap();
        let a = kd.knn(&q, 11).unwrap();
        assert_eq!(recall(&a, &t), 1.0);
    }
}

#[test]
fn active_refined_high_recall_at_paper_resolution() {
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(50_000, 303)));
    let brute = BruteEngine::new(ds.clone());
    let active = ActiveEngine::new(
        ds,
        3000,
        ActiveParams {
            mode: SearchMode::Refined,
            tolerance: 2,
            r0_policy: R0Policy::Density,
            ..Default::default()
        },
    )
    .unwrap();
    let queries = generate_queries(30, 2, 304);
    let mut total = 0.0;
    for q in &queries {
        let t = brute.knn(q, 11).unwrap();
        let a = active.knn(q, 11).unwrap();
        total += recall(&a, &t);
    }
    let avg = total / queries.len() as f64;
    assert!(avg > 0.85, "avg recall {avg}");
}

#[test]
fn all_engines_handle_same_query_surface() {
    let ds = Arc::new(generate(&SyntheticSpec::blobs(4000, 3, 305)));
    let engines: Vec<Box<dyn NnEngine>> = vec![
        Box::new(BruteEngine::new(ds.clone())),
        Box::new(KdTreeEngine::build(ds.clone())),
        Box::new(LshEngine::build(ds.clone(), LshParams::default())),
        Box::new(ActiveEngine::new(ds, 1000, ActiveParams::default()).unwrap()),
    ];
    // query at the class-0 blob center so every engine (including the
    // bucket-local LSH) has candidates nearby
    let q = [0.8, 0.5];
    for e in &engines {
        let hits = e.knn(&q, 7).unwrap();
        assert!(!hits.is_empty(), "{}", e.name());
        assert!(hits.len() <= 7, "{}", e.name());
        for w in hits.windows(2) {
            assert!(w[0].dist <= w[1].dist, "{} not sorted", e.name());
        }
        let label = e.classify(&q, 7).unwrap();
        assert!(label < 3, "{}", e.name());
        // invalid input surface behaves uniformly
        assert!(e.knn(&q, 0).is_err(), "{}", e.name());
    }
}

#[test]
fn classification_agreement_matches_paper_band() {
    // the paper reports "up to 98%" agreement on uniform data at
    // 3000² with k = 11; we require ≥ 90% on a 30k-point instance
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(30_000, 306)));
    let brute = BruteEngine::new(ds.clone());
    let active = ActiveEngine::new(ds, 3000, ActiveParams::default()).unwrap();
    let queries = generate_queries(100, 2, 307);
    let mut agree = 0;
    for q in &queries {
        if active.classify(q, 11).unwrap() == brute.classify(q, 11).unwrap() {
            agree += 1;
        }
    }
    assert!(agree >= 90, "agreement {agree}/100");
}

#[test]
fn active_work_is_sublinear_in_n() {
    // the paper's headline: active-search cost does not grow with N
    let queries = generate_queries(10, 2, 308);
    let mut works = Vec::new();
    for &n in &[10_000usize, 100_000] {
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(n, 309)));
        let active = ActiveEngine::new(
            ds,
            3000,
            ActiveParams { r0_policy: R0Policy::Density, ..Default::default() },
        )
        .unwrap();
        let mut total_work = 0u64;
        for q in &queries {
            let (_, st) = active.knn_stats(q, 11).unwrap();
            total_work += st.work;
        }
        works.push(total_work);
    }
    // 10× the data must NOT cost 10× the pixels; allow 3× headroom
    assert!(
        works[1] < works[0] * 3,
        "work grew with N: {works:?}"
    );
}
