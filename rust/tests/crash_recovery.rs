//! Crash-recovery acceptance: a snapshot torn at ANY byte boundary
//! must never stop the server from booting — the torn generation is
//! quarantined, the previous generation serves, and HEALTH reports
//! `status=ok` once the listener is up. Plus wire-hardening e2e:
//! oversized and garbage request lines never take the server down.

use std::fs;
use std::path::PathBuf;
use std::sync::Arc;

use asnn::coordinator::server::Client;
use asnn::coordinator::{IoLimits, Metrics, Request, Response, Router, Server};
use asnn::data::io as dio;
use asnn::data::synthetic::{generate, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;
use asnn::engine::NnEngine;
use asnn::grid::{snapshot as grid_snapshot, MultiGrid};
use asnn::store::{self, ChaosWriter, SnapshotStore};
use asnn::util::rng::Rng;

fn state_dir(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("asnn-crash-recovery-{}-{name}", std::process::id()));
    let _ = fs::remove_dir_all(&p);
    p
}

/// The acceptance loop: tear the newest grid snapshot at EVERY byte
/// boundary; after each tear the full recovery path (boot scan →
/// quarantine → previous generation → engine restore) must produce a
/// working engine.
#[test]
fn every_truncation_point_recovers_to_previous_generation() {
    let dir = state_dir("every-byte");
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(24, 701)));
    let grid = MultiGrid::build(&ds, 16).unwrap();
    let payload = grid_snapshot::to_bytes(&grid);

    let s = SnapshotStore::new(dir.clone(), "grid", 4);
    s.save(&payload).unwrap(); // gen 1
    let (_, gen2_path) = s.save(&payload).unwrap(); // gen 2: the fallback
    let (_, gen3_path) = s.save(&payload).unwrap(); // gen 3: will be torn
    let full = fs::read(&gen3_path).unwrap();
    assert_eq!(fs::read(&gen2_path).unwrap(), full);

    for crash_at in 0..full.len() as u64 {
        let persisted = ChaosWriter::torn_write(&gen3_path, &full, crash_at).unwrap();
        assert_eq!(persisted, crash_at);

        // boot-time recovery pass quarantines the torn file...
        let report = store::recover(&dir).unwrap();
        assert_eq!(
            report.quarantined.len(),
            1,
            "crash_at={crash_at}: torn file not quarantined"
        );
        // ...and the previous generation still loads
        let loaded = s.load_latest().unwrap().unwrap();
        assert_eq!(loaded.seq, 2, "crash_at={crash_at}");
        assert_eq!(loaded.payload, payload, "crash_at={crash_at}");

        // the recovered payload rebuilds a working engine
        let restored = grid_snapshot::from_bytes(&loaded.payload).unwrap();
        let engine =
            ActiveEngine::restore(restored, ds.clone(), ActiveParams::default()).unwrap();
        assert!(!engine.knn(&[0.5, 0.5], 3).unwrap().is_empty(), "crash_at={crash_at}");

        // reset for the next truncation point
        for q in &report.quarantined {
            fs::remove_file(q).unwrap();
        }
    }
    fs::remove_dir_all(&dir).ok();
}

/// End-to-end acceptance: both newest snapshots (dataset + grid) are
/// torn mid-write; the server boots anyway, serves correct answers
/// from the previous generation, reports `status=ok` over HEALTH, and
/// counts the quarantined files in STATS.
#[test]
fn torn_snapshots_server_boots_serves_and_reports_ok() {
    let dir = state_dir("e2e");
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(400, 702)));
    let grid = MultiGrid::build(&ds, 64).unwrap();
    let ds_payload = dio::dataset_to_bytes(&ds);
    let grid_payload = grid_snapshot::to_bytes(&grid);

    let ds_store = SnapshotStore::new(dir.clone(), "dataset", 3);
    let grid_store = SnapshotStore::new(dir.clone(), "grid", 3);
    ds_store.save(&ds_payload).unwrap();
    grid_store.save(&grid_payload).unwrap();
    // newest generations crash mid-write
    let (_, torn_ds) = ds_store.save(&ds_payload).unwrap();
    let (_, torn_grid) = grid_store.save(&grid_payload).unwrap();
    let full = fs::read(&torn_ds).unwrap();
    ChaosWriter::torn_write(&torn_ds, &full, (full.len() / 2) as u64).unwrap();
    let full = fs::read(&torn_grid).unwrap();
    ChaosWriter::torn_write(&torn_grid, &full, (full.len() / 3) as u64).unwrap();

    // boot exactly like cmd_serve: recovery pass, warm boot, serve
    let metrics = Arc::new(Metrics::new());
    metrics.set_recovering(true);
    let report = store::recover(&dir).unwrap();
    metrics.record_corrupt_quarantined(report.quarantined.len() as u64);
    assert_eq!(report.quarantined.len(), 2, "{}", report.summary());

    let ds_snap = ds_store.load_latest().unwrap().unwrap();
    let booted = Arc::new(dio::dataset_from_bytes(&ds_snap.payload).unwrap());
    assert_eq!(booted.len(), ds.len());
    let grid_snap = grid_store.load_latest().unwrap().unwrap();
    let restored = grid_snapshot::from_bytes(&grid_snap.payload).unwrap();
    let active = Arc::new(
        ActiveEngine::restore(restored, booted.clone(), ActiveParams::default()).unwrap(),
    );

    let mut router = Router::new("active", Arc::clone(&metrics));
    router.register("brute", Arc::new(BruteEngine::new(booted.clone())));
    router.register("active", Arc::clone(&active) as Arc<dyn NnEngine>);
    let handle = Server::new(Arc::new(router), 2).spawn("127.0.0.1:0").unwrap();
    metrics.set_recovering(false);

    let mut client = Client::connect(&handle.addr).unwrap();
    match client.call(&Request::Health).unwrap() {
        Response::Text(t) => assert!(t.contains("status=ok"), "{t}"),
        other => panic!("{other:?}"),
    }
    // the restored index answers like a fresh build
    let fresh = ActiveEngine::new(ds.clone(), 64, ActiveParams::default()).unwrap();
    let want: Vec<u32> = fresh.knn(&[0.4, 0.6], 5).unwrap().iter().map(|h| h.id).collect();
    match client.call(&Request::Knn { k: 5, x: 0.4, y: 0.6, engine: None }).unwrap() {
        Response::Neighbors(hits) => {
            let got: Vec<u32> = hits.iter().map(|h| h.id).collect();
            assert_eq!(got, want);
        }
        other => panic!("{other:?}"),
    }
    match client.call(&Request::Stats).unwrap() {
        Response::Text(t) => assert!(t.contains("corrupt_quarantined=2"), "{t}"),
        other => panic!("{other:?}"),
    }
    handle.shutdown();
    fs::remove_dir_all(&dir).ok();
}

/// Wire hardening e2e: oversized lines get a structured rejection and
/// random garbage never kills the server — a fresh client still gets
/// `pong` after the abuse.
#[test]
fn hostile_wire_input_never_kills_the_server() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let ds = Arc::new(generate(&SyntheticSpec::paper_default(500, 703)));
    let mut router = Router::new("brute", Arc::new(Metrics::new()));
    router.register("brute", Arc::new(BruteEngine::new(ds)));
    let router = Arc::new(router);
    let handle = Server::new(Arc::clone(&router), 2)
        .with_io_limits(IoLimits { max_line_bytes: 256, ..IoLimits::default() })
        .spawn("127.0.0.1:0")
        .unwrap();

    // oversized line: structured rejection, then the connection closes
    let stream = TcpStream::connect(handle.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer.write_all(&[b'X'; 4096]).unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR too-long"), "{line}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0);

    // garbage lines (including non-UTF-8 bytes) each get an ERR
    // response on a connection that stays up
    let mut rng = Rng::new(704);
    let stream = TcpStream::connect(handle.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    for round in 0..25 {
        let len = 1 + rng.below(80) as usize;
        // any bytes except newline (would split the line) and
        // whitespace (an all-whitespace line is silently skipped by
        // the server, which would stall this lock-step read loop)
        let mut junk = vec![b'\xfe'];
        junk.extend((0..len).map(|_| {
            let b = rng.below(256) as u8;
            if b == b'\n' || b.is_ascii_whitespace() {
                b'?'
            } else {
                b
            }
        }));
        junk.push(b'\n');
        writer.write_all(&junk).unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        assert!(reader.read_line(&mut resp).unwrap() > 0, "round {round}");
        assert!(resp.starts_with("ERR"), "round {round}: {resp:?}");
    }

    // after all the abuse a normal client still gets served
    let mut client = Client::connect(&handle.addr).unwrap();
    assert_eq!(client.call(&Request::Ping).unwrap(), Response::Text("pong".into()));
    assert!(router.metrics().snapshot().oversize_rejected >= 1);
    handle.shutdown();
}
