//! End-to-end coordinator integration: server + router + engines over
//! real TCP, including concurrent load and the batcher.

use std::sync::Arc;
use std::time::Duration;

use asnn::coordinator::batcher::Batcher;
use asnn::coordinator::server::Client;
use asnn::coordinator::{BatchEntry, Metrics, Request, Response, Router, Server, ThreadPool};
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;
use asnn::engine::kdtree::KdTreeEngine;

fn full_router(n: usize, seed: u64) -> Router {
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(n, seed)));
    let mut router = Router::new("active", Arc::new(Metrics::new()));
    router.register("brute", Arc::new(BruteEngine::new(ds.clone())));
    router.register("kdtree", Arc::new(KdTreeEngine::build(ds.clone())));
    router.register(
        "active",
        Arc::new(ActiveEngine::new(ds, 1000, ActiveParams::default()).unwrap()),
    );
    router
}

#[test]
fn serve_knn_and_classify_over_tcp() {
    let handle = Server::new(Arc::new(full_router(5000, 501)), 2)
        .spawn("127.0.0.1:0")
        .unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    match c.call(&Request::Knn { k: 11, x: 0.4, y: 0.6, engine: None }).unwrap() {
        Response::Neighbors(hits) => {
            assert!(hits.len() <= 11 && !hits.is_empty());
        }
        other => panic!("{other:?}"),
    }
    match c
        .call(&Request::Classify { k: 11, x: 0.4, y: 0.6, engine: Some("brute".into()) })
        .unwrap()
    {
        Response::Label(l) => assert!(l < 3),
        other => panic!("{other:?}"),
    }
    handle.shutdown();
}

#[test]
fn engines_agree_through_the_wire() {
    let handle = Server::new(Arc::new(full_router(3000, 502)), 2)
        .spawn("127.0.0.1:0")
        .unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    // exact engines must return identical id sets over TCP too
    let get_ids = |c: &mut Client, engine: &str| -> Vec<u32> {
        match c
            .call(&Request::Knn { k: 9, x: 0.3, y: 0.3, engine: Some(engine.into()) })
            .unwrap()
        {
            Response::Neighbors(hits) => {
                let mut v: Vec<u32> = hits.iter().map(|h| h.id).collect();
                v.sort();
                v
            }
            other => panic!("{other:?}"),
        }
    };
    assert_eq!(get_ids(&mut c, "brute"), get_ids(&mut c, "kdtree"));
    handle.shutdown();
}

#[test]
fn sustained_concurrent_load_with_metrics() {
    let router = Arc::new(full_router(10_000, 503));
    let handle = Server::new(router.clone(), 4).spawn("127.0.0.1:0").unwrap();
    let addr = handle.addr;
    let queries = generate_queries(20, 2, 504);
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let queries = queries.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                for q in &queries {
                    match c
                        .call(&Request::Knn { k: 5, x: q[0], y: q[1], engine: None })
                        .unwrap()
                    {
                        Response::Neighbors(_) => {}
                        other => panic!("thread {t}: {other:?}"),
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let snap = router.metrics().snapshot();
    assert_eq!(snap.knn_requests, 80);
    assert_eq!(snap.errors, 0);
    assert!(snap.knn_p99_us > 0.0);
    handle.shutdown();
}

#[test]
fn batcher_feeds_batch_artifact_shape() {
    // simulate the coordinator's batching of same-window queries
    let (tx, rx) = std::sync::mpsc::channel::<Vec<usize>>();
    let batcher = Batcher::new(16, Duration::from_millis(5), move |batch: Vec<usize>| {
        tx.send(batch).unwrap();
    });
    for i in 0..40 {
        assert!(batcher.submit(i));
    }
    drop(batcher);
    let mut seen = 0;
    let mut max_batch = 0;
    while let Ok(batch) = rx.try_recv() {
        assert!(batch.len() <= 16);
        max_batch = max_batch.max(batch.len());
        seen += batch.len();
    }
    assert_eq!(seen, 40);
    assert!(max_batch > 1, "no batching happened");
}

#[test]
fn knnb_round_trips_over_tcp_with_batch_accounting() {
    let mut router = full_router(4000, 506);
    router.set_batch_pool(Arc::new(ThreadPool::new(2)));
    let router = Arc::new(router);
    let handle = Server::new(router.clone(), 2).spawn("127.0.0.1:0").unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let queries: Vec<[f64; 2]> =
        (0..5).map(|i| [0.1 + 0.15 * i as f64, 0.9 - 0.15 * i as f64]).collect();
    let resp = c
        .call(&Request::Knnb { k: 7, queries: queries.clone(), engine: Some("brute".into()) })
        .unwrap();
    let entries = match resp {
        Response::Batch(entries) => entries,
        other => panic!("{other:?}"),
    };
    assert_eq!(entries.len(), 5);
    // each batch entry must match the same query asked individually
    // (both sides round-trip the same wire formatting, so exact equality)
    for (entry, q) in entries.iter().zip(&queries) {
        let single = c
            .call(&Request::Knn { k: 7, x: q[0], y: q[1], engine: Some("brute".into()) })
            .unwrap();
        match (entry, single) {
            (BatchEntry::Hits(batch_hits), Response::Neighbors(hits)) => {
                assert_eq!(batch_hits, &hits)
            }
            (e, s) => panic!("{e:?} vs {s:?}"),
        }
    }
    let snap = router.metrics().snapshot();
    assert_eq!(snap.batches, 1);
    assert_eq!(snap.batched_queries, 5);
    assert_eq!(snap.errors, 0);
    handle.shutdown();
}

#[test]
fn batching_lane_serves_concurrent_engine_less_knns() {
    let router = Arc::new(full_router(4000, 507));
    router.attach_batch_lane(8, Duration::from_millis(50), None);
    let handle = Server::new(router.clone(), 4).spawn("127.0.0.1:0").unwrap();
    let addr = handle.addr;
    let threads: Vec<_> = (0..6)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let x = 0.1 + 0.12 * t as f64;
                match c.call(&Request::Knn { k: 5, x, y: 0.5, engine: None }).unwrap() {
                    Response::Neighbors(hits) => assert!(!hits.is_empty() && hits.len() <= 5),
                    other => panic!("thread {t}: {other:?}"),
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let snap = router.metrics().snapshot();
    assert_eq!(snap.knn_requests, 6);
    assert_eq!(snap.errors, 0);
    assert!(snap.batches >= 1, "lane never flushed a batch");
    assert_eq!(snap.batched_queries, 6);
    handle.shutdown();
}

#[test]
fn quit_closes_connection_cleanly() {
    let handle = Server::new(Arc::new(full_router(1000, 505)), 1)
        .spawn("127.0.0.1:0")
        .unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    assert_eq!(c.call(&Request::Quit).unwrap(), Response::Text("bye".into()));
    // further calls fail because the server side closed
    assert!(c.call(&Request::Ping).is_err());
    handle.shutdown();
}
