//! CLI smoke tests: run the `asnn` binary end-to-end as a subprocess.

use std::path::PathBuf;
use std::process::Command;

fn asnn_bin() -> PathBuf {
    // target dir layout: .../target/<profile>/deps/<this test>; the
    // binary sits two levels up
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("asnn");
    p
}

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(asnn_bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn asnn");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (stdout, _, ok) = run(&["help"]);
    assert!(ok);
    for sub in ["gen-data", "query", "classify", "serve", "viz"] {
        assert!(stdout.contains(sub), "missing {sub}: {stdout}");
    }
}

#[test]
fn unknown_subcommand_fails_with_message() {
    let (_, stderr, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn gen_data_and_info_roundtrip() {
    let tmp = std::env::temp_dir().join(format!("asnn-cli-{}.csv", std::process::id()));
    let tmp_str = tmp.to_str().unwrap();
    let (stdout, stderr, ok) =
        run(&["gen-data", "--n", "500", "--out", tmp_str, "--seed", "9"]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("wrote 500 points"), "{stdout}");
    let (stdout, stderr, ok) = run(&[
        "info",
        "--data",
        tmp_str,
        "--resolution",
        "200",
        "--n",
        "500",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("n=500"), "{stdout}");
    std::fs::remove_file(tmp).ok();
}

#[test]
fn query_returns_k_rows() {
    let (stdout, stderr, ok) = run(&[
        "query", "--n", "2000", "--k", "5", "--x", "0.5", "--y", "0.5", "--engine", "brute",
        "--resolution", "500",
    ]);
    assert!(ok, "{stderr}");
    let rows = stdout.lines().filter(|l| l.trim_start().starts_with("id=")).count();
    assert_eq!(rows, 5, "{stdout}");
}

#[test]
fn classify_reports_agreement() {
    let (stdout, stderr, ok) = run(&[
        "classify", "--n", "5000", "--queries", "20", "--engine", "active", "--resolution",
        "1000",
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("agreement="), "{stdout}");
}

#[test]
fn viz_writes_ppm_files() {
    let out = std::env::temp_dir().join(format!("asnn-viz-{}", std::process::id()));
    let (stdout, stderr, ok) = run(&["viz", "fig1", "--out", out.to_str().unwrap()]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("fig1"), "{stdout}");
    assert!(out.join("fig1_vectors.ppm").exists());
    assert!(out.join("fig1_image.ppm").exists());
    std::fs::remove_dir_all(out).ok();
}

#[test]
fn bad_config_value_rejected() {
    let (_, stderr, ok) = run(&["query", "--n", "100", "--k", "oops", "--x", "0", "--y", "0"]);
    assert!(!ok);
    assert!(stderr.contains("cannot parse"), "{stderr}");
}
