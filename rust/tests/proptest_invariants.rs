//! Property-based invariants, driven by the in-repo PRNG (the proptest
//! crate is not in the offline vendor set — each property runs against
//! hundreds of randomized cases with shrink-free reporting of the
//! failing seed).

use std::sync::Arc;

use asnn::active::radius::{RadiusPolicy, Step};
use asnn::active::scan;
use asnn::config::{Metric, SearchMode};
use asnn::data::soa::{SoaMirror, BLOCK};
use asnn::data::synthetic::{generate, SyntheticSpec};
use asnn::data::Dataset;
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;
use asnn::engine::kdtree::KdTreeEngine;
use asnn::engine::{NnEngine, TopK};
use asnn::grid::{MultiGrid, Pyramid};
use asnn::util::rng::Rng;

/// Property: fast row-span scan ≡ naive per-pixel scan, both metrics,
/// for random centers/radii including image borders.
#[test]
fn prop_scan_equivalence() {
    let ds = generate(&SyntheticSpec::paper_default(3000, 601));
    let g = MultiGrid::build(&ds, 257).unwrap(); // odd resolution on purpose
    let mut rng = Rng::new(602);
    for case in 0..300 {
        let cx = rng.below(257) as u32;
        let cy = rng.below(257) as u32;
        let r = rng.below(90) as u32;
        for metric in [Metric::L2, Metric::L1] {
            let fast = scan::count_in_disk(&g, cx, cy, r, metric);
            let naive = scan::count_in_disk_naive(&g, cx, cy, r, metric);
            assert_eq!(fast, naive, "case {case}: cx={cx} cy={cy} r={r} {metric:?}");
        }
    }
}

/// Property: TopK(k) over any stream = sorted prefix of the full sort.
#[test]
fn prop_topk_matches_sort() {
    let mut rng = Rng::new(603);
    for case in 0..200 {
        let n = 1 + rng.below(200) as usize;
        let k = 1 + rng.below(n as u64) as usize;
        let dists: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mut top = TopK::new(k);
        for (i, &d) in dists.iter().enumerate() {
            top.push(asnn::engine::Neighbor { id: i as u32, dist: d, label: 0 });
        }
        let got: Vec<f64> = top.into_sorted().iter().map(|x| x.dist).collect();
        let mut want = dists.clone();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        want.truncate(k);
        assert_eq!(got.len(), k, "case {case}");
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-15, "case {case}");
        }
    }
}

/// Property: the radius policy always terminates within max_iters and,
/// under any monotone count function, Done/Settle circles hold ≥ k
/// points whenever any radius does.
#[test]
fn prop_radius_policy_terminates() {
    let mut rng = Rng::new(604);
    for case in 0..300 {
        let k = 1 + rng.below(50) as usize;
        let density = 10f64.powf(rng.uniform(-4.0, 0.5));
        let jitter = rng.uniform(0.0, 0.3);
        // monotone count model with noise rounded to integers
        let count = |r: u32| -> u64 {
            let area = std::f64::consts::PI * (r as f64).powi(2);
            ((area * density) * (1.0 + jitter * ((r % 7) as f64 / 7.0))).round() as u64
        };
        let max_iters = 64;
        let mut policy = RadiusPolicy::new(k, 0, max_iters, 1_000_000);
        let mut r = 1 + rng.below(500) as u32;
        let mut iters = 0;
        loop {
            iters += 1;
            assert!(iters <= max_iters, "case {case} did not terminate");
            let n = count(r);
            match policy.step(r, n) {
                Step::Done => {
                    assert_eq!(n as usize, k, "case {case}");
                    break;
                }
                Step::Settle(rs) => {
                    assert!(count(rs) >= k as u64, "case {case}: settle under k");
                    break;
                }
                Step::Exhausted => break,
                Step::Continue(next) => {
                    assert!(next >= 1);
                    r = next;
                }
            }
        }
    }
}

/// Property: kd-tree = brute force on random datasets of random sizes,
/// including duplicates and degenerate (collinear) data.
#[test]
fn prop_kdtree_exactness() {
    let mut rng = Rng::new(605);
    for case in 0..40 {
        let n = 2 + rng.below(400) as usize;
        let k = 1 + rng.below(n.min(20) as u64) as usize;
        let mut pts = Vec::with_capacity(n * 2);
        let degenerate = case % 5 == 0;
        for _ in 0..n {
            let x = rng.next_f64();
            // every 5th case: all points on a line (splitting stress)
            let y = if degenerate { 0.5 } else { rng.next_f64() };
            pts.push(x);
            pts.push(y);
            if case % 7 == 0 && pts.len() >= 4 {
                // inject duplicates
                let px = pts[0];
                let py = pts[1];
                let len = pts.len();
                pts[len - 2] = px;
                pts[len - 1] = py;
            }
        }
        let labels = vec![0u16; n];
        let ds = Arc::new(Dataset::new(2, pts, labels, 1).unwrap());
        let brute = BruteEngine::new(ds.clone());
        let kd = KdTreeEngine::build(ds);
        let q = [rng.next_f64(), rng.next_f64()];
        let a = kd.knn(&q, k).unwrap();
        let b = brute.knn(&q, k).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert!(
                (x.dist - y.dist).abs() < 1e-12,
                "case {case}: kd {} vs brute {}",
                x.dist,
                y.dist
            );
        }
    }
}

/// Property: grid pixel mapping is total (never panics, always in
/// range) for arbitrary finite inputs including far outliers.
#[test]
fn prop_pixel_mapping_total() {
    let ds = generate(&SyntheticSpec::paper_default(100, 606));
    let g = MultiGrid::build(&ds, 128).unwrap();
    let geom = g.geometry();
    let mut rng = Rng::new(607);
    for _ in 0..1000 {
        let x = rng.uniform(-1e6, 1e6);
        let y = rng.uniform(-1e6, 1e6);
        let (px, py) = geom.pixel_of(x, y);
        assert!(px < 128 && py < 128);
    }
}

/// Property: the wire protocol parsers are total — no input, however
/// malformed (random token soup or raw bytes through lossy UTF-8),
/// panics `Request::parse` or `Response::parse`. Hostile clients can
/// only ever produce `Err`, never take a worker thread down.
#[test]
fn prop_protocol_parse_total() {
    use asnn::coordinator::{Request, Response};
    let tokens = [
        "KNN", "KNNB", "CLASSIFY", "PING", "STATS", "STATS2", "TRACE", "HEALTH", "QUIT",
        "OK", "ERR", "B", "json", "text", "stages", "engines", "coordinator",
        "1", "-3", "0.5", "1e308", "-1e-308", "nan", "inf", "18446744073709551616", "x",
        "=", ";", "\"", "\\", "\u{7f}", "🦀",
    ];
    let mut rng = Rng::new(609);
    for _ in 0..2000 {
        // token soup: plausible-looking but malformed command lines
        let len = rng.below(8) as usize;
        let mut line = String::new();
        for i in 0..len {
            if i > 0 {
                line.push(' ');
            }
            line.push_str(tokens[rng.below(tokens.len() as u64) as usize]);
        }
        let _ = Request::parse(&line);
        let _ = Response::parse(&line);

        // raw byte soup (what a lossy-decoded garbage line looks like)
        let blen = rng.below(64) as usize;
        let bytes: Vec<u8> = (0..blen).map(|_| rng.below(256) as u8).collect();
        let text = String::from_utf8_lossy(&bytes);
        let _ = Request::parse(&text);
        let _ = Response::parse(&text);
    }
}

/// Property: the STATS2 observability document round-trips — for
/// arbitrary recorded stage spans and engine counters, render → parse
/// → re-render is byte-identical, the parsed document rebuilds the
/// exact snapshot, and restoring the export into a fresh recorder
/// reproduces the same document (what a warm restart does).
#[test]
fn prop_obs_snapshot_json_roundtrips() {
    use asnn::obs::{Json, ObsSnapshot, Recorder, Stage};
    let mut rng = Rng::new(617);
    let engines = ["brute", "kdtree", "active", "active-pjrt"];
    for case in 0..100u64 {
        let r = Recorder::new();
        for _ in 0..rng.below(200) {
            let stage = Stage::ALL[rng.below(Stage::ALL.len() as u64) as usize];
            r.record_stage(stage, rng.below(10_000_000_000));
            let name = engines[rng.below(engines.len() as u64) as usize];
            match rng.below(3) {
                0 => r.record_engine_ok(name, rng.below(1_000_000_000)),
                1 => r.record_engine_err(name),
                _ => r.record_engine_batch(name, rng.below(64)),
            }
        }
        let snap = r.snapshot();
        let rendered = snap.to_json().render();
        let parsed = Json::parse(&rendered).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(parsed.render(), rendered, "case {case}");
        assert_eq!(ObsSnapshot::from_json(&parsed).unwrap(), snap, "case {case}");

        let fresh = Recorder::new();
        fresh.restore_bytes(&r.export_bytes()).unwrap();
        assert_eq!(fresh.snapshot().to_json().render(), rendered, "case {case}");
    }
}

/// Property: `knn_batch` ≡ sequential `knn` for any batch size, query
/// order, and k — on both the exact brute engine and the active engine
/// (whose batched path reuses per-thread scratch across queries, so
/// this also proves the scratch is fully reset between queries).
#[test]
fn prop_knn_batch_matches_sequential() {
    let mut rng = Rng::new(610);
    for case in 0..25u64 {
        let n = 50 + rng.below(400) as usize;
        let ds = Arc::new(generate(&SyntheticSpec::paper_default(n, 611 + case)));
        let brute = BruteEngine::new(ds.clone());
        let mode = if case % 2 == 0 { SearchMode::Refined } else { SearchMode::Approx };
        let active =
            ActiveEngine::new(ds.clone(), 128, ActiveParams { mode, ..ActiveParams::default() })
                .unwrap();
        let b = 1 + rng.below(24) as usize;
        let k = 1 + rng.below(10) as usize;
        let queries: Vec<[f64; 2]> = (0..b).map(|_| [rng.next_f64(), rng.next_f64()]).collect();
        let views: Vec<&[f64]> = queries.iter().map(|q| q.as_slice()).collect();
        for engine in [&brute as &dyn NnEngine, &active] {
            let batched = engine.knn_batch(&views, k);
            assert_eq!(batched.len(), b, "case {case}");
            for (i, (got, q)) in batched.into_iter().zip(&queries).enumerate() {
                match (got, engine.knn(q, k)) {
                    (Ok(g), Ok(w)) => assert_eq!(g, w, "case {case} query {i}"),
                    (Err(g), Err(w)) => {
                        assert_eq!(g.to_string(), w.to_string(), "case {case} query {i}")
                    }
                    (g, w) => panic!("case {case} query {i}: batched {g:?} vs single {w:?}"),
                }
            }
        }
    }
}

/// Property: the blocked SoA f32 distance kernel matches the f64
/// scalar oracle within f32 tolerance, for arbitrary id subsets
/// (sized to hit full and remainder blocks) and arbitrary queries;
/// and a top-k selection over the f32 distances agrees with the f64
/// top-k rank-by-rank on distance (ids may differ on near-ties).
#[test]
fn prop_soa_topk_matches_f64_oracle() {
    let mut rng = Rng::new(612);
    for case in 0..60u64 {
        let n = 1 + rng.below(300) as usize;
        let ds = generate(&SyntheticSpec::paper_default(n, 613 + case));
        let soa = SoaMirror::build(&ds);
        assert_eq!(soa.len(), n, "case {case}");
        let q = [rng.next_f64(), rng.next_f64()];
        let qf = [q[0] as f32, q[1] as f32];
        let m = 1 + rng.below((n + BLOCK) as u64) as usize;
        let ids: Vec<u32> = (0..m).map(|_| rng.below(n as u64) as u32).collect();
        let mut dists = Vec::new();
        soa.dist2_ids_into(&ids, &qf, &mut dists);
        assert_eq!(dists.len(), ids.len(), "case {case}");
        for (&id, &d32) in ids.iter().zip(&dists) {
            let d64 = ds.dist2(id as usize, &q);
            assert!(
                (d32 as f64 - d64).abs() <= 1e-5 * (1.0 + d64),
                "case {case}: id {id} f32 {d32} vs f64 {d64}"
            );
        }
        // rank-by-rank top-k agreement on distance values
        let k = 1 + rng.below(ids.len() as u64) as usize;
        let mut by32: Vec<f32> = dists.clone();
        by32.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut by64: Vec<f64> = ids.iter().map(|&id| ds.dist2(id as usize, &q)).collect();
        by64.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for i in 0..k {
            let d32 = by32[i] as f64;
            let d64 = by64[i];
            assert!(
                (d32 - d64).abs() <= 1e-4 * (1.0 + d64),
                "case {case} rank {i}: f32 {d32} vs f64 {d64}"
            );
        }
    }
}

/// Property: pyramid coarse disk bounds are sound — an upper bound on
/// the exact disk count at every level, and exact at level 0 — for
/// random centers/radii, both metrics, odd and even resolutions.
#[test]
fn prop_pyramid_disk_bound_sound() {
    let mut rng = Rng::new(614);
    for (res, n, seed) in [(257usize, 2000usize, 615u64), (128, 1500, 616)] {
        let ds = generate(&SyntheticSpec::paper_default(n, seed));
        let g = MultiGrid::build(&ds, res).unwrap();
        let p = Pyramid::build(&g);
        for case in 0..150 {
            let cx = rng.below(res as u64) as u32;
            let cy = rng.below(res as u64) as u32;
            let r = rng.below((res / 2) as u64) as u32;
            for metric in [Metric::L2, Metric::L1] {
                let exact = scan::count_in_disk(&g, cx, cy, r, metric);
                for level in 0..p.num_levels() {
                    let bound = p.count_in_disk_bound(level, cx, cy, r, metric);
                    assert!(
                        bound >= exact,
                        "case {case} res={res} level={level} cx={cx} cy={cy} r={r} \
                         {metric:?}: bound {bound} < exact {exact}"
                    );
                }
                assert_eq!(
                    p.count_in_disk_bound(0, cx, cy, r, metric),
                    exact,
                    "case {case} res={res} cx={cx} cy={cy} r={r} {metric:?}"
                );
            }
        }
    }
}

/// Property: Eq. 1 is scale-consistent — doubling both k and n leaves
/// the next radius unchanged.
#[test]
fn prop_eq1_scale_invariance() {
    let mut rng = Rng::new(608);
    for _ in 0..500 {
        let r = 1 + rng.below(3000) as u32;
        let k = 1 + rng.below(100);
        let n = 1 + rng.below(10_000);
        let a = RadiusPolicy::eq1(r, k, n);
        let b = RadiusPolicy::eq1(r, k * 2, n * 2);
        assert_eq!(a, b, "r={r} k={k} n={n}");
    }
}
