//! Chaos end-to-end: drive the real TCP server through a fault-injecting
//! engine and prove the resilience layer holds — panics are isolated,
//! slow calls hit deadlines, failures trip circuit breakers onto the
//! fallback chain, a full queue sheds with a structured overload error,
//! and through all of it the server stays up and keeps answering
//! correct k-NN queries, with the damage visible in STATS.

use std::sync::Arc;
use std::time::Duration;

use asnn::coordinator::resilience::{BreakerPolicy, ResiliencePolicy, RetryPolicy};
use asnn::coordinator::server::Client;
use asnn::coordinator::{ErrCode, Metrics, Request, Response, Router, Server};
use asnn::data::synthetic::{generate, SyntheticSpec};
use asnn::engine::brute::BruteEngine;
use asnn::engine::chaos::{ChaosConfig, ChaosEngine};
use asnn::engine::NnEngine;

/// Router whose default engine is chaos-wrapped brute force, with the
/// plain brute engine as the only fallback. Failures through "chaos"
/// must land on "brute" and produce exact answers.
fn chaos_router(chaos: ChaosConfig, policy: ResiliencePolicy, n: usize, seed: u64) -> Router {
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(n, seed)));
    let brute: Arc<dyn NnEngine> = Arc::new(BruteEngine::new(ds));
    let mut router = Router::with_policy("chaos", Arc::new(Metrics::new()), policy);
    router.register("chaos", Arc::new(ChaosEngine::new(Arc::clone(&brute), chaos)));
    router.register("brute", brute);
    router.set_fallback_chain(vec!["brute".into()]);
    router
}

fn knn_ids(c: &mut Client, k: usize, engine: Option<&str>) -> Vec<u32> {
    match c
        .call(&Request::Knn { k, x: 0.42, y: 0.58, engine: engine.map(String::from) })
        .unwrap()
    {
        Response::Neighbors(hits) => {
            assert_eq!(hits.len(), k);
            let mut ids: Vec<u32> = hits.iter().map(|h| h.id).collect();
            ids.sort();
            ids
        }
        other => panic!("expected neighbors, got {other:?}"),
    }
}

fn stats(c: &mut Client) -> String {
    match c.call(&Request::Stats).unwrap() {
        Response::Text(t) => t,
        other => panic!("expected stats text, got {other:?}"),
    }
}

/// Pull `field=<u64>` out of a STATS line.
fn stat(text: &str, field: &str) -> u64 {
    let key = format!("{field}=");
    text.split_whitespace()
        .find_map(|tok| tok.strip_prefix(&key))
        .unwrap_or_else(|| panic!("missing {field} in {text:?}"))
        .parse()
        .unwrap_or_else(|_| panic!("non-numeric {field} in {text:?}"))
}

#[test]
fn panicking_engine_trips_breaker_onto_fallback_and_server_stays_up() {
    let policy = ResiliencePolicy {
        breaker: BreakerPolicy {
            threshold: 3,
            cooldown: Duration::from_secs(60),
            ..BreakerPolicy::default()
        },
        ..ResiliencePolicy::default()
    };
    let router = Arc::new(chaos_router(
        ChaosConfig { panic_rate: 1.0, seed: 1, ..ChaosConfig::default() },
        policy,
        2000,
        601,
    ));
    let handle = Server::new(Arc::clone(&router), 2).spawn("127.0.0.1:0").unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();

    // every request is answered correctly despite the default engine
    // panicking on every call
    let truth = knn_ids(&mut c, 7, Some("brute"));
    for _ in 0..8 {
        assert_eq!(knn_ids(&mut c, 7, None), truth);
    }

    let s = stats(&mut c);
    assert!(stat(&s, "panics") >= 3, "{s}");
    assert_eq!(stat(&s, "trips"), 1, "{s}");
    assert!(stat(&s, "fallbacks") >= 8, "{s}");
    assert_eq!(stat(&s, "errors"), 0, "{s}");

    // HEALTH reports the tripped breaker and degraded status
    match c.call(&Request::Health).unwrap() {
        Response::Text(t) => {
            assert!(t.contains("status=degraded"), "{t}");
            assert!(t.contains("chaos:open"), "{t}");
            assert!(t.contains("brute:closed"), "{t}");
        }
        other => panic!("{other:?}"),
    }

    // the server is still fully alive
    assert_eq!(c.call(&Request::Ping).unwrap(), Response::Text("pong".into()));
    handle.shutdown();
}

#[test]
fn injected_errors_are_retried_then_fall_back() {
    let policy = ResiliencePolicy {
        retry: RetryPolicy { max_retries: 2, backoff: Duration::from_micros(200) },
        breaker: BreakerPolicy {
            threshold: 4,
            cooldown: Duration::from_secs(60),
            ..BreakerPolicy::default()
        },
        ..ResiliencePolicy::default()
    };
    let router = Arc::new(chaos_router(
        ChaosConfig { error_rate: 1.0, seed: 2, ..ChaosConfig::default() },
        policy,
        2000,
        602,
    ));
    let handle = Server::new(Arc::clone(&router), 2).spawn("127.0.0.1:0").unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();

    let truth = knn_ids(&mut c, 5, Some("brute"));
    for _ in 0..4 {
        assert_eq!(knn_ids(&mut c, 5, None), truth);
    }

    let s = stats(&mut c);
    assert!(stat(&s, "retries") > 0, "{s}");
    assert!(stat(&s, "fallbacks") >= 4, "{s}");
    // one breaker failure per request (retries count inside the
    // attempt): the 4th consecutive failed request trips it
    assert_eq!(stat(&s, "trips"), 1, "{s}");
    assert_eq!(stat(&s, "errors"), 0, "{s}");
    handle.shutdown();
}

#[test]
fn latency_beyond_deadline_times_out_onto_fallback() {
    let policy = ResiliencePolicy {
        deadline: Some(Duration::from_millis(40)),
        breaker: BreakerPolicy {
            threshold: 2,
            cooldown: Duration::from_secs(60),
            ..BreakerPolicy::default()
        },
        ..ResiliencePolicy::default()
    };
    let router = Arc::new(chaos_router(
        ChaosConfig {
            latency_rate: 1.0,
            latency: Duration::from_millis(400),
            seed: 3,
            ..ChaosConfig::default()
        },
        policy,
        2000,
        603,
    ));
    let handle = Server::new(Arc::clone(&router), 2).spawn("127.0.0.1:0").unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();

    let truth = knn_ids(&mut c, 5, Some("brute"));
    let t0 = std::time::Instant::now();
    for _ in 0..3 {
        assert_eq!(knn_ids(&mut c, 5, None), truth);
    }
    // 3 requests against a 400ms-slow engine with a 40ms deadline:
    // far faster than riding out the injected latency every time
    // (breaker opens after 2 timeouts, request 3 skips straight to brute)
    assert!(t0.elapsed() < Duration::from_millis(900), "{:?}", t0.elapsed());

    let s = stats(&mut c);
    assert!(stat(&s, "timeouts") >= 2, "{s}");
    assert_eq!(stat(&s, "trips"), 1, "{s}");
    assert!(stat(&s, "fallbacks") >= 3, "{s}");
    handle.shutdown();
}

#[test]
fn full_queue_sheds_with_structured_overload_error() {
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(1000, 604)));
    let mut router = Router::new("brute", Arc::new(Metrics::new()));
    router.register("brute", Arc::new(BruteEngine::new(ds)));
    let router = Arc::new(router);
    let handle = Server::new(Arc::clone(&router), 2)
        .with_max_inflight(1)
        .spawn("127.0.0.1:0")
        .unwrap();

    // first connection takes the only admission slot
    let mut holder = Client::connect(&handle.addr).unwrap();
    assert_eq!(holder.call(&Request::Ping).unwrap(), Response::Text("pong".into()));

    // the next connections are shed, not queued and not dropped silently
    for _ in 0..3 {
        let mut extra = Client::connect(&handle.addr).unwrap();
        match extra.call(&Request::Knn { k: 3, x: 0.5, y: 0.5, engine: None }).unwrap() {
            Response::Error { code, message } => {
                assert_eq!(code, ErrCode::Overload);
                assert!(message.contains("retry"), "{message}");
            }
            other => panic!("expected overload error, got {other:?}"),
        }
    }

    // the held connection still works and sees the shed count
    let s = stats(&mut holder);
    assert_eq!(stat(&s, "shed"), 3, "{s}");
    assert!(knn_ids(&mut holder, 3, None).len() == 3);
    handle.shutdown();
}

#[test]
fn mixed_chaos_under_concurrent_load_never_loses_a_request() {
    let policy = ResiliencePolicy {
        deadline: Some(Duration::from_millis(150)),
        retry: RetryPolicy { max_retries: 1, backoff: Duration::from_micros(200) },
        breaker: BreakerPolicy {
            threshold: 4,
            cooldown: Duration::from_millis(200),
            ..BreakerPolicy::default()
        },
        ..ResiliencePolicy::default()
    };
    let router = Arc::new(chaos_router(
        ChaosConfig {
            error_rate: 0.3,
            panic_rate: 0.2,
            latency_rate: 0.2,
            latency: Duration::from_millis(30),
            seed: 4,
            ..ChaosConfig::default()
        },
        policy,
        5000,
        605,
    ));
    let handle = Server::new(Arc::clone(&router), 4).spawn("127.0.0.1:0").unwrap();
    let addr = handle.addr;

    let threads: Vec<_> = (0..3)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                for i in 0..15 {
                    match c
                        .call(&Request::Knn { k: 5, x: 0.3, y: 0.6, engine: None })
                        .unwrap()
                    {
                        Response::Neighbors(hits) => assert_eq!(hits.len(), 5),
                        other => panic!("thread {t} req {i}: {other:?}"),
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    // afterwards: still up, still exact
    let mut c = Client::connect(&addr).unwrap();
    let truth = knn_ids(&mut c, 9, Some("brute"));
    assert_eq!(knn_ids(&mut c, 9, None), truth);
    let s = stats(&mut c);
    assert_eq!(stat(&s, "errors"), 0, "{s}");
    assert_eq!(stat(&s, "knn") , 47, "{s}"); // 45 load + 2 verification
    handle.shutdown();
}

#[test]
fn hedged_request_wins_with_fallback_answer_while_slow_engine_still_running() {
    // the default engine takes 400ms per call; with a 30ms hedge delay
    // the router fires the same query at brute and returns its answer
    // long before the slow engine finishes
    let policy = ResiliencePolicy {
        hedge_delay: Some(Duration::from_millis(30)),
        ..ResiliencePolicy::default()
    };
    let router = Arc::new(chaos_router(
        ChaosConfig {
            latency_rate: 1.0,
            latency: Duration::from_millis(400),
            seed: 6,
            ..ChaosConfig::default()
        },
        policy,
        2000,
        607,
    ));
    let handle = Server::new(Arc::clone(&router), 2).spawn("127.0.0.1:0").unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();

    let truth = knn_ids(&mut c, 7, Some("brute"));
    let t0 = std::time::Instant::now();
    assert_eq!(knn_ids(&mut c, 7, None), truth);
    // far less than the 400ms the hedged-against engine needs
    assert!(t0.elapsed() < Duration::from_millis(250), "{:?}", t0.elapsed());

    let s = stats(&mut c);
    assert_eq!(stat(&s, "hedges"), 1, "{s}");
    assert_eq!(stat(&s, "hedge_wins"), 1, "{s}");
    assert!(stat(&s, "fallbacks") >= 1, "{s}");
    assert_eq!(stat(&s, "errors"), 0, "{s}");
    handle.shutdown();
}

#[test]
fn request_budget_bounds_total_latency_across_retries() {
    // every call sleeps 80ms then errors; with 3 retries allowed the
    // old per-attempt accounting could burn 300ms+, but the 150ms
    // request budget clamps attempt 2's deadline and stops the retry
    // loop, so the client hears "budget exhausted" at ~150ms
    let policy = ResiliencePolicy {
        budget: Some(Duration::from_millis(150)),
        retry: RetryPolicy { max_retries: 3, backoff: Duration::from_millis(20) },
        fallback_enabled: false,
        ..ResiliencePolicy::default()
    };
    let router = Arc::new(chaos_router(
        ChaosConfig {
            latency_rate: 1.0,
            latency: Duration::from_millis(80),
            error_rate: 1.0,
            seed: 7,
            ..ChaosConfig::default()
        },
        policy,
        1500,
        608,
    ));
    let handle = Server::new(Arc::clone(&router), 2).spawn("127.0.0.1:0").unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();

    let t0 = std::time::Instant::now();
    match c.call(&Request::Knn { k: 5, x: 0.42, y: 0.58, engine: None }).unwrap() {
        Response::Error { code, message } => {
            assert_eq!(code, ErrCode::Timeout);
            assert!(message.contains("budget"), "{message}");
        }
        other => panic!("expected budget timeout, got {other:?}"),
    }
    // budget (150ms) plus one attempt's grace, nowhere near the
    // 4 × (80ms + backoff) an unbudgeted retry loop would take
    assert!(t0.elapsed() < Duration::from_millis(400), "{:?}", t0.elapsed());

    let s = stats(&mut c);
    assert_eq!(stat(&s, "budget_exhausted"), 1, "{s}");
    assert!(stat(&s, "timeouts") >= 1, "{s}");
    assert!(stat(&s, "retries") >= 1, "{s}");
    assert_eq!(stat(&s, "errors"), 1, "{s}");
    handle.shutdown();
}

#[test]
fn flapping_engine_stays_open_until_probe_success_window_passes() {
    // deterministic flapping: chaos calls 0..4 fail, 4..8 succeed.
    // threshold 2 trips the breaker inside the sick window; with
    // probe_successes = 3 the breaker must survive two failed probes
    // (re-trips) and then three consecutive healthy probes to close.
    let policy = ResiliencePolicy {
        breaker: BreakerPolicy {
            threshold: 2,
            cooldown: Duration::from_millis(60),
            probe_successes: 3,
        },
        ..ResiliencePolicy::default()
    };
    let router = Arc::new(chaos_router(
        ChaosConfig { flap_period: 4, seed: 8, ..ChaosConfig::default() },
        policy,
        1500,
        609,
    ));
    let handle = Server::new(Arc::clone(&router), 2).spawn("127.0.0.1:0").unwrap();
    let mut c = Client::connect(&handle.addr).unwrap();
    let truth = knn_ids(&mut c, 5, Some("brute"));
    let health = |c: &mut Client| match c.call(&Request::Health).unwrap() {
        Response::Text(t) => t,
        other => panic!("{other:?}"),
    };

    // chaos calls 0 and 1 (sick): second failure trips the breaker
    assert_eq!(knn_ids(&mut c, 5, None), truth);
    assert_eq!(knn_ids(&mut c, 5, None), truth);
    // open breaker: chaos skipped entirely, no call consumed
    assert_eq!(knn_ids(&mut c, 5, None), truth);
    assert!(health(&mut c).contains("chaos:open"));

    // two probes land in the sick window (calls 2 and 3): each re-trips
    for _ in 0..2 {
        std::thread::sleep(Duration::from_millis(80));
        assert_eq!(knn_ids(&mut c, 5, None), truth);
        assert!(health(&mut c).contains("chaos:open"));
    }
    let s = stats(&mut c);
    assert_eq!(stat(&s, "trips"), 3, "{s}");

    // healthy window (calls 4..8): probes succeed, but the breaker must
    // not close until three of them have passed
    std::thread::sleep(Duration::from_millis(80));
    assert_eq!(knn_ids(&mut c, 5, None), truth); // probe success 1 of 3
    let h = health(&mut c);
    assert!(h.contains("chaos:half-open"), "{h}");
    assert_eq!(knn_ids(&mut c, 5, None), truth); // 2 of 3
    let h = health(&mut c);
    assert!(h.contains("chaos:half-open"), "{h}");
    assert_eq!(knn_ids(&mut c, 5, None), truth); // 3 of 3: closed
    let h = health(&mut c);
    assert!(h.contains("chaos:closed"), "{h}");
    assert!(h.contains("status=ok"), "{h}");

    let s = stats(&mut c);
    assert_eq!(stat(&s, "trips"), 3, "{s}");
    assert_eq!(stat(&s, "errors"), 0, "{s}");
    handle.shutdown();
}

#[test]
fn shutdown_under_load_drains_in_flight_requests_and_reports_draining() {
    // a 150ms-slow engine serves a request that is mid-flight when
    // shutdown starts: the drain must let it finish, HEALTH must report
    // status=draining meanwhile, and shutdown must return well within
    // the drain deadline
    let router = Arc::new(chaos_router(
        ChaosConfig {
            latency_rate: 1.0,
            latency: Duration::from_millis(150),
            seed: 9,
            ..ChaosConfig::default()
        },
        ResiliencePolicy::default(),
        1500,
        610,
    ));
    let handle = Server::new(Arc::clone(&router), 4)
        .with_drain_deadline(Duration::from_millis(1000))
        .spawn("127.0.0.1:0")
        .unwrap();
    let addr = handle.addr;

    // a probing connection established before the drain begins
    let mut prober = Client::connect(&addr).unwrap();
    assert_eq!(prober.call(&Request::Ping).unwrap(), Response::Text("pong".into()));

    // fire the slow request; it has ~110ms left when shutdown starts
    let slow = std::thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        c.call(&Request::Knn { k: 5, x: 0.42, y: 0.58, engine: None }).unwrap()
    });
    std::thread::sleep(Duration::from_millis(40));

    let shutdown = std::thread::spawn(move || {
        let t0 = std::time::Instant::now();
        handle.shutdown();
        t0.elapsed()
    });
    std::thread::sleep(Duration::from_millis(20));

    // mid-drain: HEALTH on the pre-drain connection reports draining
    match prober.call(&Request::Health).unwrap() {
        Response::Text(t) => assert!(t.contains("status=draining"), "{t}"),
        other => panic!("{other:?}"),
    }

    // the in-flight request completed normally during the drain
    match slow.join().unwrap() {
        Response::Neighbors(hits) => assert_eq!(hits.len(), 5),
        other => panic!("{other:?}"),
    }
    // and the whole shutdown stayed far below the 1s drain deadline
    // (it returns as soon as the last connection finishes)
    let drained_in = shutdown.join().unwrap();
    assert!(drained_in < Duration::from_millis(900), "{drained_in:?}");
}
