//! Integration: AOT artifacts load, compile, and execute via PJRT, and
//! agree with the native rust scan. Tests are skipped (pass trivially)
//! when `artifacts/manifest.toml` is absent — run `make artifacts`.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use asnn::active::scan;
use asnn::config::Metric;
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::active_pjrt::ActivePjrtEngine;
use asnn::engine::NnEngine;
use asnn::grid::MultiGrid;
use asnn::runtime::RuntimeService;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.toml").exists().then_some(dir)
}

fn service() -> Option<RuntimeService> {
    artifacts_dir().map(|d| RuntimeService::spawn(d).expect("spawn runtime"))
}

#[test]
fn registry_exposes_disk_count_ladder() {
    let Some(svc) = service() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let windows = svc.disk_count_windows();
    assert!(!windows.is_empty());
    assert!(windows.windows(2).all(|w| w[0] < w[1]));
    assert_eq!(svc.platform(), "cpu");
}

#[test]
fn disk_count_matches_native_scan() {
    let Some(svc) = service() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let ds = generate(&SyntheticSpec::paper_default(5000, 201));
    let grid = MultiGrid::build(&ds, 512).unwrap();
    let w = svc.disk_count_windows()[0];
    let name = format!("disk_count_w{w}_b1");
    for &(cx, cy, r) in &[(256u32, 256u32, 10u32), (256, 256, 25), (40, 470, 15)] {
        assert!(2 * r as usize + 1 <= w);
        let mut window = vec![0f32; 3 * w * w];
        grid.crop_classes_f32(cx, cy, w, &mut window);
        let out = svc.disk_count(&name, window, r as f32, 11.0, false).unwrap();
        let native = scan::count_in_disk(&grid, cx, cy, r, Metric::L2);
        assert_eq!(out.total as u64, native, "cx={cx} cy={cy} r={r}");
        let cls_sum: f32 = out.class_counts.iter().sum();
        assert_eq!(cls_sum as u64, native);
    }
}

#[test]
fn disk_count_l1_matches_native() {
    let Some(svc) = service() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let ds = generate(&SyntheticSpec::paper_default(5000, 202));
    let grid = MultiGrid::build(&ds, 512).unwrap();
    let w = svc.disk_count_windows()[0];
    let name = format!("disk_count_w{w}_b1");
    let (cx, cy, r) = (200u32, 300u32, 20u32);
    let mut window = vec![0f32; 3 * w * w];
    grid.crop_classes_f32(cx, cy, w, &mut window);
    let out = svc.disk_count(&name, window, r as f32, 11.0, true).unwrap();
    let native = scan::count_in_disk(&grid, cx, cy, r, Metric::L1);
    assert_eq!(out.total as u64, native);
}

#[test]
fn eq1_next_radius_matches_rust_policy() {
    let Some(svc) = service() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    use asnn::active::radius::RadiusPolicy;
    let ds = generate(&SyntheticSpec::paper_default(20000, 203));
    let grid = MultiGrid::build(&ds, 512).unwrap();
    let w = svc.disk_count_windows()[0];
    let name = format!("disk_count_w{w}_b1");
    let (cx, cy, r) = (256u32, 256u32, 14u32);
    let mut window = vec![0f32; 3 * w * w];
    grid.crop_classes_f32(cx, cy, w, &mut window);
    let out = svc.disk_count(&name, window, r as f32, 11.0, false).unwrap();
    let n = out.total as u64;
    if n > 0 {
        assert_eq!(out.next_r as u32, RadiusPolicy::eq1(r, 11, n));
    }
}

#[test]
fn batched_disk_count_matches_single() {
    let Some(svc) = service() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let ds = generate(&SyntheticSpec::paper_default(8000, 204));
    let grid = MultiGrid::build(&ds, 512).unwrap();
    let w = svc.disk_count_windows()[0];
    let b1 = format!("disk_count_w{w}_b1");
    let b16 = format!("disk_count_w{w}_b16");
    if svc.meta(&b16).is_none() {
        eprintln!("skipped: no b16 artifact");
        return;
    }
    let centers: Vec<(u32, u32)> = (0..16).map(|i| (100 + i * 20, 150 + i * 10)).collect();
    let r = 12.0f32;
    let mut windows = vec![0f32; 16 * 3 * w * w];
    for (i, &(cx, cy)) in centers.iter().enumerate() {
        grid.crop_classes_f32(cx, cy, w, &mut windows[i * 3 * w * w..(i + 1) * 3 * w * w]);
    }
    let outs = svc
        .disk_count_batch(&b16, windows, vec![r; 16], 11.0, false)
        .unwrap();
    assert_eq!(outs.len(), 16);
    for (i, &(cx, cy)) in centers.iter().enumerate() {
        let mut window = vec![0f32; 3 * w * w];
        grid.crop_classes_f32(cx, cy, w, &mut window);
        let single = svc.disk_count(&b1, window, r, 11.0, false).unwrap();
        assert_eq!(outs[i].total, single.total, "query {i}");
    }
}

#[test]
fn neighbor_scan_finds_occupied_pixels() {
    let Some(svc) = service() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let ds = generate(&SyntheticSpec::paper_default(300, 205));
    let grid = MultiGrid::build(&ds, 512).unwrap();
    let w = svc.disk_count_windows()[0];
    let name = format!("neighbor_scan_w{w}");
    if svc.meta(&name).is_none() {
        eprintln!("skipped: no neighbor_scan artifact");
        return;
    }
    let (cx, cy, r) = (256u32, 256u32, 30u32);
    let mut window = vec![0f32; w * w];
    grid.crop_total_f32(cx, cy, w, &mut window);
    let out = svc.neighbor_scan(&name, window.clone(), r as f32, false).unwrap();
    let native = scan::count_in_disk(&grid, cx, cy, r, Metric::L2);
    let hits = out.indices.iter().filter(|&&i| i >= 0).count();
    // every occupied in-circle pixel (≤ k_max of them) must be returned
    let occupied_pixels = {
        let mut n = 0u64;
        let half = (w / 2) as i64;
        for wy in 0..w as i64 {
            for wx in 0..w as i64 {
                let dx = wx - half;
                let dy = wy - half;
                if dx * dx + dy * dy <= (r as i64) * (r as i64)
                    && window[(wy * w as i64 + wx) as usize] > 0.0
                {
                    n += 1;
                }
            }
        }
        n
    };
    assert_eq!(hits as u64, occupied_pixels.min(32));
    assert!(native >= hits as u64); // points ≥ pixels
    // distances ascend among live entries
    let live: Vec<f32> = out.dists.iter().copied().filter(|d| d.is_finite()).collect();
    for pair in live.windows(2) {
        assert!(pair[0] <= pair[1]);
    }
}

#[test]
fn knn_chunk_matches_exact_distances() {
    let Some(svc) = service() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let Some(meta) = svc.meta("knn_chunk_b1") else {
        eprintln!("skipped: no knn_chunk artifact");
        return;
    };
    let chunk_len = meta.chunk;
    let ds = generate(&SyntheticSpec::paper_default(1000, 206));
    let mut chunk = vec![0f32; chunk_len * 2];
    for i in 0..1000 {
        chunk[i * 2] = ds.point(i)[0] as f32;
        chunk[i * 2 + 1] = ds.point(i)[1] as f32;
    }
    let q = [0.5f32, 0.5f32];
    let out = svc.knn_chunk("knn_chunk_b1", q.to_vec(), chunk, 1000).unwrap();
    let mut exact: Vec<(f64, usize)> = (0..1000)
        .map(|i| (ds.dist2(i, &[0.5, 0.5]), i))
        .collect();
    exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for j in 0..5 {
        assert_eq!(out.indices[j] as usize, exact[j].1, "rank {j}");
        assert!((out.dists[j] as f64 - exact[j].0).abs() < 1e-5);
    }
    // padding masked out
    assert!(out.indices.iter().all(|&i| i < 1000));
}

#[test]
fn batch_search_agrees_with_sequential() {
    let Some(svc) = service() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(15_000, 209)));
    let params = ActiveParams { tolerance: 1, ..Default::default() };
    let engine = ActivePjrtEngine::new(ds, 1000, params, svc).unwrap();
    let queries = generate_queries(20, 2, 210);
    let batched = engine.batch_search(&queries, 11).unwrap();
    assert_eq!(batched.len(), queries.len());
    for (q, b) in queries.iter().zip(&batched) {
        let single = engine.search(q, 11).unwrap();
        assert_eq!(b.r, single.r, "final radius differs for {q:?}");
        assert_eq!(b.n_inside, single.n_inside);
        assert_eq!(b.trace.converged, single.trace.converged);
    }
    // batched classification runs end-to-end
    let labels = engine.batch_classify(&queries, 11).unwrap();
    assert_eq!(labels.len(), queries.len());
    assert!(labels.iter().all(|&l| l < 3));
}

#[test]
fn pjrt_engine_agrees_with_native_active() {
    let Some(svc) = service() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(20_000, 207)));
    let params = ActiveParams { tolerance: 1, ..Default::default() };
    let native = ActiveEngine::new(ds.clone(), 1000, params.clone()).unwrap();
    let pjrt = ActivePjrtEngine::new(ds, 1000, params, svc).unwrap();
    for q in generate_queries(5, 2, 208) {
        let a = native.knn(&q, 11).unwrap();
        let b = pjrt.knn(&q, 11).unwrap();
        let ia: Vec<u32> = a.iter().map(|n| n.id).collect();
        let ib: Vec<u32> = b.iter().map(|n| n.id).collect();
        assert_eq!(ia, ib, "query {q:?}");
    }
}
