//! ABL-METRIC — the paper's §3 L1 remark: "When the L1 distance is
//! taken, the computational cost could be extremely cheap, while the
//! result would be more roughly approximated than the Euclidean
//! distance."
//!
//! We measure both sides: pixels scanned per query (the cost model —
//! the L1 diamond covers ~2r² pixels vs. the L2 disk's ~πr²) and
//! classification agreement vs. exact (L2) kNN.
//!
//! Run: `cargo bench --bench metric_ablation`

use std::sync::Arc;

use asnn::bench::Table;
use asnn::config::Metric;
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;
use asnn::engine::NnEngine;
use asnn::util::timer::Timer;

const N: usize = 30_000;
const QUERIES: usize = 200;
const K: usize = 11;
const RESOLUTION: usize = 3000;

fn main() {
    let data = Arc::new(generate(&SyntheticSpec::paper_default(N, 991)));
    let queries = generate_queries(QUERIES, 2, 992);
    let brute = BruteEngine::new(data.clone());
    let truth: Vec<u16> = queries.iter().map(|q| brute.classify(q, K).unwrap()).collect();

    let mut table = Table::new(
        "ABL-METRIC L2 disk vs L1 diamond (N=30k, k=11, 3000^2)",
        &["metric", "agreement_pct", "mean_pixels_per_query", "mean_query_us", "knn_recall_pct"],
    );
    for metric in [Metric::L2, Metric::L1] {
        let engine = ActiveEngine::new(
            data.clone(),
            RESOLUTION,
            ActiveParams { metric, ..Default::default() },
        )
        .unwrap();
        let mut agree = 0usize;
        let mut pixels = 0u64;
        let mut recall_sum = 0.0f64;
        let t = Timer::new();
        for (q, want) in queries.iter().zip(&truth) {
            if engine.classify(q, K).unwrap() == *want {
                agree += 1;
            }
            let (hits, st) = engine.knn_stats(q, K).unwrap();
            pixels += st.work;
            let exact = brute.knn(q, K).unwrap();
            let ids: Vec<u32> = exact.iter().map(|n| n.id).collect();
            recall_sum +=
                hits.iter().filter(|h| ids.contains(&h.id)).count() as f64 / K as f64;
        }
        let secs = t.elapsed_secs();
        table.row(&[
            metric.name().to_string(),
            format!("{:.1}", 100.0 * agree as f64 / QUERIES as f64),
            format!("{:.0}", pixels as f64 / QUERIES as f64),
            format!("{:.1}", secs * 1e6 / (2 * QUERIES) as f64),
            format!("{:.1}", 100.0 * recall_sum / QUERIES as f64),
        ]);
    }
    table.print();
    println!("expected shape: L1 scans fewer pixels (2r² vs πr²) but recalls/agrees slightly worse.");
}
