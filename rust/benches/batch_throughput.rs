//! PERF — batched query engine throughput: queries/sec of one-at-a-time
//! KNN dispatch vs a single KNNB batch fanned across a dedicated worker
//! pool (1/2/4/8 workers). Both paths go through the full router
//! (breakers, budgets, fallback chain), so the delta is the real
//! serving-side win, not a kernel microbenchmark. Emits
//! `BENCH_batch_throughput.json` next to the printed table so the
//! speedup series is scriptable.
//!
//! Run: `cargo bench --bench batch_throughput`

use std::sync::Arc;

use asnn::bench::{run, BenchSpec, Table};
use asnn::coordinator::{Metrics, Request, Router, ThreadPool};
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;

const N_POINTS: usize = 20_000;
const RESOLUTION: usize = 1000;
const K: usize = 10;
const BATCH: usize = 64;

fn main() {
    let ds = Arc::new(generate(&SyntheticSpec::paper_default(N_POINTS, 1401)));
    let active =
        Arc::new(ActiveEngine::new(ds.clone(), RESOLUTION, ActiveParams::default()).unwrap());
    let brute = Arc::new(BruteEngine::new(ds));
    let make_router = |pool_workers: Option<usize>| {
        let mut r = Router::new("active", Arc::new(Metrics::new()));
        r.register("active", active.clone());
        r.register("brute", brute.clone());
        if let Some(w) = pool_workers {
            r.set_batch_pool(Arc::new(ThreadPool::new(w)));
        }
        Arc::new(r)
    };
    let queries: Vec<[f64; 2]> =
        generate_queries(BATCH, 2, 1402).into_iter().map(|q| [q[0], q[1]]).collect();

    // baseline: one router request per query
    let single_router = make_router(None);
    let single = run(&BenchSpec::quick(format!("single KNN x{BATCH}")), || {
        for q in &queries {
            let resp = single_router.handle(&Request::Knn { k: K, x: q[0], y: q[1], engine: None });
            std::hint::black_box(resp);
        }
    });
    let single_qps = BATCH as f64 / single.mean_secs;

    let mut table = Table::new(
        "PERF batch throughput: KNNB vs single KNN (20k pts, k=10, batch=64)",
        &["mode", "workers", "qps", "speedup"],
    );
    table.row(&["single".into(), "-".into(), format!("{single_qps:.0}"), "1.00x".into()]);

    let mut batched_json = Vec::new();
    for &w in &[1usize, 2, 4, 8] {
        let router = make_router(Some(w));
        let req = Request::Knnb { k: K, queries: queries.clone(), engine: None };
        let res = run(&BenchSpec::quick(format!("knnb w{w}")), || {
            std::hint::black_box(router.handle(&req));
        });
        let qps = BATCH as f64 / res.mean_secs;
        let speedup = qps / single_qps;
        table.row(&[
            "knnb".into(),
            w.to_string(),
            format!("{qps:.0}"),
            format!("{speedup:.2}x"),
        ]);
        batched_json
            .push(format!("    {{\"workers\": {w}, \"qps\": {qps:.1}, \"speedup\": {speedup:.3}}}"));
    }
    table.print();

    let json = format!(
        "{{\n  \"bench\": \"batch_throughput\",\n  \"n_points\": {N_POINTS},\n  \
         \"resolution\": {RESOLUTION},\n  \"k\": {K},\n  \"batch_size\": {BATCH},\n  \
         \"single_qps\": {single_qps:.1},\n  \"batched\": [\n{}\n  ]\n}}\n",
        batched_json.join(",\n")
    );
    std::fs::write("BENCH_batch_throughput.json", &json).expect("write BENCH_batch_throughput.json");
    println!("wrote BENCH_batch_throughput.json");
}
