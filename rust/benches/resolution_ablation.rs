//! ABL-RES — the paper's §2/§3 resolution trade-off, quantified:
//! "If the data points are transformed onto a low resolution image,
//! some points might overlap … If the resolution increases, the
//! algorithm requires a bigger memory size and has to check more
//! pixels."
//!
//! For each resolution we report: classification agreement with exact
//! kNN, mean per-query time, index memory, overlap fraction, and mean
//! Eq.-1 iterations.
//!
//! Run: `cargo bench --bench resolution_ablation`

use std::sync::Arc;

use asnn::bench::Table;
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::brute::BruteEngine;
use asnn::engine::NnEngine;
use asnn::util::timer::Timer;

const N: usize = 30_000;
const QUERIES: usize = 150;
const K: usize = 11;

fn main() {
    let data = Arc::new(generate(&SyntheticSpec::paper_default(N, 881)));
    let queries = generate_queries(QUERIES, 2, 882);
    let brute = BruteEngine::new(data.clone());
    let truth: Vec<u16> = queries.iter().map(|q| brute.classify(q, K).unwrap()).collect();

    let mut table = Table::new(
        "ABL-RES resolution vs accuracy/time/memory (N=30k, k=11)",
        &[
            "resolution",
            "agreement_pct",
            "mean_query_us",
            "index_mib",
            "overlap_frac",
            "mean_iters",
        ],
    );
    for &res in &[512usize, 1024, 2048, 3000, 4096] {
        let engine = ActiveEngine::new(data.clone(), res, ActiveParams::default()).unwrap();
        let mem = engine.grid().memory_bytes() as f64 / (1024.0 * 1024.0);
        let overlap = engine.grid().overlap_fraction();
        let t = Timer::new();
        let mut agree = 0usize;
        let mut iters = 0u64;
        for (q, want) in queries.iter().zip(&truth) {
            if engine.classify(q, K).unwrap() == *want {
                agree += 1;
            }
            let (_, st) = engine.knn_stats(q, K).unwrap();
            iters += st.iterations as u64;
        }
        let secs = t.elapsed_secs();
        table.row(&[
            res.to_string(),
            format!("{:.1}", 100.0 * agree as f64 / QUERIES as f64),
            format!("{:.1}", secs * 1e6 / (2 * QUERIES) as f64),
            format!("{mem:.1}"),
            format!("{overlap:.4}"),
            format!("{:.1}", iters as f64 / QUERIES as f64),
        ]);
        eprintln!("res={res} done");
    }
    table.print();
    println!(
        "expected shape: agreement rises then saturates with resolution; \
         memory grows ~quadratically; overlap falls."
    );

    // ABL-SKIP — does the coarse-to-fine radius fast-forward pay for
    // itself? Same data/queries, coarse_skip toggled per resolution.
    // Accuracy should match by construction (the fast-forward only
    // skips radii a pyramid upper bound proves under-filled), so the
    // interesting column is mean_query_us. Results + the default
    // decision live in docs/PERFORMANCE.md.
    let mut skip_table = Table::new(
        "ABL-SKIP coarse_skip on/off (N=30k, k=11)",
        &["resolution", "coarse_skip", "agreement_pct", "mean_query_us", "mean_iters"],
    );
    for &res in &[512usize, 1024, 2048, 3000, 4096] {
        for &skip in &[false, true] {
            let params = ActiveParams { coarse_skip: skip, ..ActiveParams::default() };
            let engine = ActiveEngine::new(data.clone(), res, params).unwrap();
            let t = Timer::new();
            let mut agree = 0usize;
            let mut iters = 0u64;
            for (q, want) in queries.iter().zip(&truth) {
                if engine.classify(q, K).unwrap() == *want {
                    agree += 1;
                }
                let (_, st) = engine.knn_stats(q, K).unwrap();
                iters += st.iterations as u64;
            }
            let secs = t.elapsed_secs();
            skip_table.row(&[
                res.to_string(),
                skip.to_string(),
                format!("{:.1}", 100.0 * agree as f64 / QUERIES as f64),
                format!("{:.1}", secs * 1e6 / (2 * QUERIES) as f64),
                format!("{:.1}", iters as f64 / QUERIES as f64),
            ]);
            eprintln!("res={res} coarse_skip={skip} done");
        }
    }
    skip_table.print();
}
