//! RUNTIME — PJRT dispatch overhead and batch amortization: what one
//! `disk_count` execution costs per window size vs. the native rust
//! scan, and how much the b16 batch artifact amortizes. Grounds the
//! §Perf discussion of when the AOT path wins (it is built for TPU-
//! sized windows; on CPU-PJRT the dispatch overhead dominates small
//! windows — measured here, not guessed).
//!
//! Skips (prints a notice) when artifacts are absent.
//!
//! Run: `cargo bench --bench runtime_overhead`

use std::path::Path;

use asnn::bench::{run, BenchResult, BenchSpec, Table};
use asnn::config::Metric;
use asnn::active::scan;
use asnn::data::synthetic::{generate, SyntheticSpec};
use asnn::grid::MultiGrid;
use asnn::runtime::RuntimeService;

fn main() {
    scan_generations();
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.toml").exists() {
        println!("runtime_overhead: artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let svc = RuntimeService::spawn(artifacts).expect("runtime");
    let ds = generate(&SyntheticSpec::paper_default(50_000, 1301));
    let grid = MultiGrid::build(&ds, 3000).unwrap();

    let mut table = Table::new(
        "RUNTIME disk_count per-call cost: PJRT artifact vs native scan",
        &["window", "pjrt_mean", "pjrt_b16_per_q", "native_mean", "ratio"],
    );
    let (cx, cy) = (1500u32, 1500u32);
    for &w in &svc.disk_count_windows() {
        let r = (w as u32 - 1) / 2;
        let name = format!("disk_count_w{w}_b1");
        let mut window = vec![0f32; 3 * w * w];
        grid.crop_classes_f32(cx, cy, w, &mut window);
        let pjrt = run(&BenchSpec::quick(format!("pjrt w{w}")), || {
            svc.disk_count(&name, window.clone(), r as f32, 11.0, false).unwrap();
        });
        // batched variant (per-query amortized)
        let b16 = format!("disk_count_w{w}_b16");
        let b16_per_q = if svc.meta(&b16).is_some() {
            let mut windows = vec![0f32; 16 * 3 * w * w];
            for i in 0..16 {
                windows[i * 3 * w * w..(i + 1) * 3 * w * w].copy_from_slice(&window);
            }
            let res = run(&BenchSpec::quick(format!("pjrt w{w} b16")), || {
                svc.disk_count_batch(&b16, windows.clone(), vec![r as f32; 16], 11.0, false)
                    .unwrap();
            });
            format!("{:.1}us", res.mean_secs * 1e6 / 16.0)
        } else {
            "n/a".into()
        };
        let native = run(&BenchSpec::quick(format!("native r{r}")), || {
            std::hint::black_box(scan::count_in_disk(&grid, cx, cy, r, Metric::L2));
        });
        table.row(&[
            w.to_string(),
            fmt(&pjrt),
            b16_per_q,
            fmt(&native),
            format!("{:.1}x", pjrt.mean_secs / native.mean_secs),
        ]);
        eprintln!("w={w} done");
    }
    table.print();
}

fn fmt(r: &BenchResult) -> String {
    format!("{:.1}us", r.mean_secs * 1e6)
}

/// §Perf: the three generations of the disk-count hot path.
/// naive O(πr²) per-pixel test → rowspan O(πr²) sequential sums →
/// prefix O(r) span lookups.
fn scan_generations() {
    let ds = generate(&SyntheticSpec::paper_default(100_000, 1302));
    let grid = MultiGrid::build(&ds, 3000).unwrap();
    let mut table = Table::new(
        "PERF-L3 disk-count generations (100k pts, 3000^2)",
        &["radius", "naive", "rowspan", "prefix", "speedup_total"],
    );
    for &r in &[50u32, 100, 300, 1000] {
        let naive = run(&BenchSpec::quick(format!("naive r{r}")), || {
            std::hint::black_box(scan::count_in_disk_naive(&grid, 1500, 1500, r, Metric::L2));
        });
        let rowspan = run(&BenchSpec::quick(format!("rowspan r{r}")), || {
            std::hint::black_box(scan::count_in_disk_rowspan(&grid, 1500, 1500, r, Metric::L2));
        });
        let prefix = run(&BenchSpec::quick(format!("prefix r{r}")), || {
            std::hint::black_box(scan::count_in_disk(&grid, 1500, 1500, r, Metric::L2));
        });
        table.row(&[
            r.to_string(),
            fmt(&naive),
            fmt(&rowspan),
            fmt(&prefix),
            format!("{:.0}x", naive.mean_secs / prefix.mean_secs),
        ]);
    }
    table.print();
}
