//! FIG3 — the paper's headline figure: elapsed time vs. N.
//!
//! Paper setup: random 2-D points, 3 classes, 100 queries classified
//! with k = 11, image fixed at 3000×3000, r₀ = 100. The paper shows
//! the original kNN growing linearly with N while active search stays
//! flat (actually *decreasing*, because sparser grids make the fixed
//! r₀ = 100 circle undershoot and the loop spends iterations growing —
//! the paper's own explanation, §3).
//!
//! Run: `cargo bench --bench fig3_scaling`
//! Full paper range (to 1e6): `ASNN_FIG3_FULL=1 cargo bench --bench fig3_scaling`

use std::path::Path;
use std::sync::Arc;

use asnn::bench::Table;
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::active_pjrt::ActivePjrtEngine;
use asnn::engine::brute::BruteEngine;
use asnn::engine::kdtree::KdTreeEngine;
use asnn::engine::NnEngine;
use asnn::runtime::RuntimeService;
use asnn::util::timer::Timer;
use asnn::viz::plot::{self, PlotSpec, Series};

const K: usize = 11;
const QUERIES: usize = 100;
const RESOLUTION: usize = 3000;

fn main() {
    let full = std::env::var("ASNN_FIG3_FULL").is_ok();
    let ns: &[usize] = if full {
        &[1_000, 3_162, 10_000, 31_623, 100_000, 316_228, 1_000_000]
    } else {
        &[1_000, 3_162, 10_000, 31_623, 100_000, 316_228]
    };
    let queries = generate_queries(QUERIES, 2, 11);
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let service = artifacts
        .join("manifest.toml")
        .exists()
        .then(|| RuntimeService::spawn(artifacts).expect("runtime"));

    let mut table = Table::new(
        "FIG3 elapsed seconds for 100 classifications vs N (k=11, 3000^2, r0=100)",
        &["n", "brute", "kdtree", "active", "active_pjrt"],
    );
    let mut s_brute = Series::new("brute (paper: blue crosses)", 'x');
    let mut s_active = Series::new("active (paper: red circles)", 'o');
    let mut s_kd = Series::new("kdtree", 'k');
    for &n in ns {
        let data = Arc::new(generate(&SyntheticSpec::paper_default(n, 300 + n as u64)));
        let brute = BruteEngine::new(data.clone());
        let kdtree = KdTreeEngine::build(data.clone());
        let active =
            ActiveEngine::new(data.clone(), RESOLUTION, ActiveParams::default()).unwrap();

        let time_engine = |e: &dyn NnEngine| -> f64 {
            let t = Timer::new();
            for q in &queries {
                e.classify(q, K).unwrap();
            }
            t.elapsed_secs()
        };
        let t_brute = time_engine(&brute);
        let t_kd = time_engine(&kdtree);
        let t_active = time_engine(&active);
        let t_pjrt = match &service {
            Some(svc) => {
                let e = ActivePjrtEngine::new(
                    data.clone(),
                    RESOLUTION,
                    ActiveParams::default(),
                    svc.clone(),
                )
                .unwrap();
                format!("{:.4}", time_engine(&e))
            }
            None => "n/a".to_string(),
        };
        table.row(&[
            n.to_string(),
            format!("{t_brute:.4}"),
            format!("{t_kd:.4}"),
            format!("{t_active:.4}"),
            t_pjrt,
        ]);
        s_brute.push(n as f64, t_brute);
        s_active.push(n as f64, t_active);
        s_kd.push(n as f64, t_kd);
        eprintln!("n={n} done (brute {t_brute:.3}s, active {t_active:.3}s)");
    }
    table.print();
    let spec = PlotSpec::new("FIG3 (reproduction): elapsed time vs N")
        .loglog()
        .labels("N (points)", "elapsed (s), 100 queries");
    println!("{}", plot::render(&spec, &[s_brute, s_kd, s_active]));
    println!(
        "expected shape: brute grows ~linearly in N; active is flat-to-decreasing \
         (fixed r0=100 wastes grow-iterations on sparse grids — paper §3)."
    );
}
