//! EXT-ENGINES — full engine comparison (extension beyond the paper):
//! recall@k and latency percentiles for every engine at serving scale,
//! the table a practitioner needs before adopting active search.
//!
//! Run: `cargo bench --bench engines_compare`

use std::path::Path;
use std::sync::Arc;

use asnn::bench::Table;
use asnn::config::SearchMode;
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::active_pjrt::ActivePjrtEngine;
use asnn::engine::brute::BruteEngine;
use asnn::engine::kdtree::KdTreeEngine;
use asnn::engine::lsh::{LshEngine, LshParams};
use asnn::engine::{Neighbor, NnEngine};
use asnn::runtime::RuntimeService;
use asnn::util::stats::percentile;
use asnn::util::timer::Timer;

const N: usize = 100_000;
const QUERIES: usize = 200;
const K: usize = 11;
const RESOLUTION: usize = 3000;

fn recall(hits: &[Neighbor], truth: &[Neighbor]) -> f64 {
    let ids: Vec<u32> = truth.iter().map(|n| n.id).collect();
    hits.iter().filter(|h| ids.contains(&h.id)).count() as f64 / truth.len() as f64
}

fn main() {
    let data = Arc::new(generate(&SyntheticSpec::paper_default(N, 1213)));
    let queries = generate_queries(QUERIES, 2, 1214);
    let brute = BruteEngine::new(data.clone());
    let truth: Vec<Vec<Neighbor>> =
        queries.iter().map(|q| brute.knn(q, K).unwrap()).collect();

    let mut engines: Vec<(Box<dyn NnEngine>, String)> = vec![
        (Box::new(BruteEngine::new(data.clone())), "brute".into()),
        (Box::new(KdTreeEngine::build(data.clone())), "kdtree".into()),
        (Box::new(LshEngine::build(data.clone(), LshParams::default())), "lsh".into()),
        (
            Box::new(
                ActiveEngine::new(data.clone(), RESOLUTION, ActiveParams::default()).unwrap(),
            ),
            "active-approx".into(),
        ),
        (
            Box::new(
                ActiveEngine::new(
                    data.clone(),
                    RESOLUTION,
                    ActiveParams { mode: SearchMode::Refined, tolerance: 2, ..Default::default() },
                )
                .unwrap(),
            ),
            "active-refined".into(),
        ),
    ];
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.toml").exists() {
        let svc = RuntimeService::spawn(artifacts).expect("runtime");
        engines.push((
            Box::new(
                ActivePjrtEngine::new(data, RESOLUTION, ActiveParams::default(), svc).unwrap(),
            ),
            "active-pjrt".into(),
        ));
    }

    let mut table = Table::new(
        "EXT-ENGINES recall@11 and latency at N=100k",
        &["engine", "recall_pct", "p50_us", "p99_us", "mean_work"],
    );
    for (engine, name) in &engines {
        let mut lat = Vec::with_capacity(QUERIES);
        let mut rec = 0.0;
        let mut work = 0u64;
        for (q, t) in queries.iter().zip(&truth) {
            let timer = Timer::new();
            let (hits, st) = engine.knn_stats(q, K).unwrap();
            lat.push(timer.elapsed_secs() * 1e6);
            rec += recall(&hits, t);
            work += st.work;
        }
        table.row(&[
            name.clone(),
            format!("{:.1}", 100.0 * rec / QUERIES as f64),
            format!("{:.1}", percentile(&mut lat.clone(), 50.0)),
            format!("{:.1}", percentile(&mut lat, 99.0)),
            format!("{}", work / QUERIES as u64),
        ]);
        eprintln!("{name} done");
    }
    table.print();
}
