//! ABL-R0 — the paper's own observation (§3): "the initial radius was
//! fixed to 100, which seems too small", which is *why* its Fig. 3
//! active curve decreases with N. We sweep r₀ (and the density-
//! informed policy extension) across two dataset sizes and report
//! iterations + time: the decreasing-curve mechanism, isolated.
//!
//! Run: `cargo bench --bench r0_ablation`

use std::sync::Arc;

use asnn::bench::Table;
use asnn::config::R0Policy;
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::NnEngine;
use asnn::util::timer::Timer;

const QUERIES: usize = 150;
const K: usize = 11;
const RESOLUTION: usize = 3000;

fn main() {
    let queries = generate_queries(QUERIES, 2, 1001);
    let mut table = Table::new(
        "ABL-R0 initial radius vs iterations/time (k=11, 3000^2)",
        &["n", "r0", "mean_iters", "mean_query_us", "converged_pct"],
    );
    for &n in &[3_000usize, 100_000] {
        let data = Arc::new(generate(&SyntheticSpec::paper_default(n, 1000 + n as u64)));
        let mut configs: Vec<(String, ActiveParams)> = [10u32, 30, 100, 300, 1000]
            .iter()
            .map(|&r0| {
                (r0.to_string(), ActiveParams { r0, ..Default::default() })
            })
            .collect();
        configs.push((
            "density".into(),
            ActiveParams { r0_policy: R0Policy::Density, ..Default::default() },
        ));
        for (label, params) in configs {
            let engine = ActiveEngine::new(data.clone(), RESOLUTION, params).unwrap();
            let mut iters = 0u64;
            let mut converged = 0usize;
            let t = Timer::new();
            for q in &queries {
                let (_, st) = engine.knn_stats(q, K).unwrap();
                iters += st.iterations as u64;
                converged += st.converged as usize;
            }
            let secs = t.elapsed_secs();
            table.row(&[
                n.to_string(),
                label,
                format!("{:.1}", iters as f64 / QUERIES as f64),
                format!("{:.1}", secs * 1e6 / QUERIES as f64),
                format!("{:.0}", 100.0 * converged as f64 / QUERIES as f64),
            ]);
        }
        eprintln!("n={n} done");
    }
    table.print();
    println!(
        "expected shape: the best fixed r0 shifts with N (dense data wants small r0); \
         the density policy tracks it automatically — explaining the paper's decreasing Fig. 3 curve."
    );
}
