//! TAB-ACC — the paper's §3 accuracy claim: classification agreement
//! of active search vs. the original kNN "up to 98%" on uniform
//! (structureless — the worst case) 2-D data at 3000² resolution.
//!
//! We sweep engine variants: the paper's approx mode, the refined
//! extension, the PJRT path, and the LSH baseline for context.
//!
//! Run: `cargo bench --bench accuracy_table`

use std::path::Path;
use std::sync::Arc;

use asnn::bench::Table;
use asnn::config::SearchMode;
use asnn::data::synthetic::{generate, generate_queries, SyntheticSpec};
use asnn::engine::active::{ActiveEngine, ActiveParams};
use asnn::engine::active_pjrt::ActivePjrtEngine;
use asnn::engine::brute::BruteEngine;
use asnn::engine::lsh::{LshEngine, LshParams};
use asnn::engine::NnEngine;
use asnn::runtime::RuntimeService;
use asnn::util::timer::Timer;

const N: usize = 50_000;
const QUERIES: usize = 200;
const K: usize = 11;
const RESOLUTION: usize = 3000;

fn main() {
    let data = Arc::new(generate(&SyntheticSpec::paper_default(N, 777)));
    let queries = generate_queries(QUERIES, 2, 778);
    let brute = BruteEngine::new(data.clone());
    let truth: Vec<u16> = queries.iter().map(|q| brute.classify(q, K).unwrap()).collect();

    let mut engines: Vec<(Box<dyn NnEngine>, String)> = vec![
        (
            Box::new(
                ActiveEngine::new(data.clone(), RESOLUTION, ActiveParams::default()).unwrap(),
            ),
            "active approx (paper)".into(),
        ),
        (
            Box::new(
                ActiveEngine::new(
                    data.clone(),
                    RESOLUTION,
                    ActiveParams { mode: SearchMode::Refined, tolerance: 2, ..Default::default() },
                )
                .unwrap(),
            ),
            "active refined (ext)".into(),
        ),
        (
            Box::new(LshEngine::build(data.clone(), LshParams::default())),
            "lsh baseline".into(),
        ),
    ];
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.toml").exists() {
        let svc = RuntimeService::spawn(artifacts).expect("runtime");
        engines.push((
            Box::new(
                ActivePjrtEngine::new(data, RESOLUTION, ActiveParams::default(), svc).unwrap(),
            ),
            "active-pjrt (AOT)".into(),
        ));
    }

    let mut table = Table::new(
        "TAB-ACC agreement with exact kNN, uniform 2-D, k=11, 3000^2 (paper: up to 98%)",
        &["engine", "agreement_pct", "queries", "elapsed_s"],
    );
    for (engine, name) in &engines {
        let t = Timer::new();
        let mut agree = 0usize;
        // the paper's vote is per-class circle counts; for the refined
        // extension the natural classifier is majority over the exact
        // re-ranked k neighbors (the same rule exact kNN uses)
        let refined = name.contains("refined");
        for (q, want) in queries.iter().zip(&truth) {
            let got = if refined {
                let hits = engine.knn(q, K).unwrap();
                asnn::engine::majority_vote(hits.iter().map(|h| h.label))
            } else {
                engine.classify(q, K).unwrap()
            };
            if got == *want {
                agree += 1;
            }
        }
        table.row(&[
            name.clone(),
            format!("{:.1}", 100.0 * agree as f64 / QUERIES as f64),
            QUERIES.to_string(),
            format!("{:.3}", t.elapsed_secs()),
        ]);
    }
    table.print();
}
